//! Proposer role (§2.2): the blocking driver around [`RoundCore`].
//!
//! A [`Proposer`] owns a ballot generator, the cluster configuration, the
//! 1-RTT cache (§2.2.1) and a retry policy. Any number of proposers can
//! run concurrently — CASPaxos has no leader — and clients may talk to
//! any of them. Per-proposer state is minimal by design: the ballot
//! counter and the (purely optional) cache.
//!
//! Calls block the calling thread; fan-out parallelism is the
//! transport's job (see [`crate::transport`]).

pub mod cache;
pub mod core;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::ballot::{Ballot, BallotGenerator};
use crate::change::ChangeFn;
use crate::error::{CasError, CasResult};
use crate::metrics::Counters;
use crate::msg::{Key, ProposerId, Request};
use crate::quorum::ClusterConfig;
use crate::rng::Rng;
use crate::state::Val;
use crate::transport::Transport;

pub use self::cache::{RttCache, DEFAULT_CACHE_CAPACITY};
pub use self::core::{
    LeaseCore, LeaseOutcome, LeaseRead, LeaseRound, LeaseStep, ReadCore, ReadStep, RoundCore,
    RoundOutcome, Step,
};

/// Consistency route for [`Proposer::get`]. Every mode is
/// linearizable; they differ only in cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadMode {
    /// Try the 1-RTT zero-write quorum read first; fall back to the
    /// identity-CAS round when the quorum disagrees or a foreign write
    /// is in flight (the default).
    Quorum,
    /// Always run the classic §2.2 identity-CAS round (two phases and a
    /// quorum of durable writes per read). The ablation baseline.
    Cas,
    /// **0-RTT read leases**: acceptors grant this proposer a
    /// time-bounded promise to reject foreign ballots on a key; while
    /// the full grant set is live (within the clock-skew bound) reads
    /// are served from local state with zero transport sends. Expired,
    /// denied or broken leases degrade to a 1-RTT grant round and then
    /// the identity-CAS round — a broken lease can only cost the fast
    /// path, never linearizability (see
    /// [`LeaseCore`](core::LeaseCore)). Tunables: [`LeaseOpts`].
    Lease,
}

/// Outcome of [`Proposer::get_or_redirect`]: a served value, or the
/// identity of the proposer whose live lease fenced the read — the
/// routing tier re-issues the read on that holder's 0-RTT path instead
/// of waiting out the skew-bounded lease window here.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RoutedRead {
    /// The read completed on this proposer.
    Val(Val),
    /// A lease denial named a foreign holder: route the read there.
    Redirect {
        /// Proposer id of the current leaseholder.
        holder: u64,
    },
}

/// What one [`Proposer::lease_round`] fan-out produced for its caller.
struct LeaseAttempt {
    /// The 1-RTT read value (grant snapshots agreed), if any.
    value: Option<Val>,
    /// The leaseholder a denying acceptor named, if any.
    holder: Option<u64>,
}

/// Tunables for [`ReadMode::Lease`].
#[derive(Debug, Clone)]
pub struct LeaseOpts {
    /// Lease length requested from each acceptor (measured on the
    /// acceptor's clock from receipt; capped server-side at 60s).
    pub duration: Duration,
    /// Clock-skew bound σ: the holder serves locally only within
    /// `duration - σ` of *sending* the grant round. Safety holds as
    /// long as no more than `fault_tolerance()` acceptor clocks drift
    /// more than σ relative to the holder over one lease window.
    pub skew_bound: Duration,
    /// Renew cadence: a read landing within this margin of expiry runs
    /// a renew round (1 RTT) instead of serving 0-RTT, keeping steady
    /// read traffic permanently lease-covered.
    pub renew_margin: Duration,
}

impl Default for LeaseOpts {
    fn default() -> Self {
        LeaseOpts {
            duration: Duration::from_secs(2),
            skew_bound: Duration::from_millis(200),
            renew_margin: Duration::from_millis(500),
        }
    }
}

/// Tunables for the retry/backoff policy.
#[derive(Debug, Clone)]
pub struct ProposerOpts {
    /// Enable the one-round-trip optimization (§2.2.1).
    pub piggyback: bool,
    /// Total attempts per change (first try + retries).
    pub max_attempts: u32,
    /// Wall-clock budget for one round's replies.
    pub round_timeout: Duration,
    /// Base backoff between attempts (exponential, jittered).
    pub backoff: Duration,
    /// How [`Proposer::get`] reads (see [`ReadMode`]).
    pub read_mode: ReadMode,
    /// Entry cap for the 1-RTT cache (§2.2.1), see
    /// [`RttCache::with_capacity`].
    pub cache_capacity: usize,
    /// Read-lease tunables (used only in [`ReadMode::Lease`]).
    pub lease: LeaseOpts,
    /// Proposer-side backpressure: when the transport reports at least
    /// this many requests already in flight ([`Transport::inflight`]),
    /// new operations are shed with [`CasError::Overloaded`] before
    /// any fan-out instead of queueing unboundedly behind a struggling
    /// connection. `0` disables the check (the default); transports
    /// that don't track in-flight depth are never shed.
    pub max_inflight: usize,
}

impl Default for ProposerOpts {
    fn default() -> Self {
        ProposerOpts {
            piggyback: true,
            max_attempts: 16,
            round_timeout: Duration::from_secs(2),
            backoff: Duration::from_micros(200),
            read_mode: ReadMode::Quorum,
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            lease: LeaseOpts::default(),
            max_inflight: 0,
        }
    }
}

/// A CASPaxos proposer bound to a transport and a cluster configuration.
pub struct Proposer {
    id: u64,
    age: AtomicU64,
    gen: Mutex<BallotGenerator>,
    cfg: RwLock<ClusterConfig>,
    /// Bumped by every [`Proposer::update_config`] (under the lease
    /// lock): lets a grant round detect that a config change — even an
    /// idempotent re-push of an identical config, which already revoked
    /// acceptor-side leases — landed while it was in flight. Structural
    /// config equality cannot see that case.
    cfg_gen: AtomicU64,
    transport: Arc<dyn Transport>,
    cache: Mutex<RttCache>,
    /// Per-key read-lease state ([`ReadMode::Lease`]).
    lease: Mutex<LeaseCore>,
    /// Epoch for the monotonic lease clock (µs since construction).
    clock_epoch: Instant,
    jitter: Mutex<Rng>,
    opts: ProposerOpts,
    /// Protocol counters (rounds, conflicts, cache hits, ...).
    pub metrics: Counters,
}

impl Proposer {
    /// Creates a proposer with default options.
    pub fn new(id: u64, cfg: ClusterConfig, transport: Arc<dyn Transport>) -> Self {
        Self::with_opts(id, cfg, transport, ProposerOpts::default())
    }

    /// Creates a proposer with explicit options.
    pub fn with_opts(
        id: u64,
        cfg: ClusterConfig,
        transport: Arc<dyn Transport>,
        opts: ProposerOpts,
    ) -> Self {
        let lease = LeaseCore::new(
            id,
            opts.lease.duration.as_micros() as u64,
            opts.lease.skew_bound.as_micros() as u64,
            opts.lease.renew_margin.as_micros() as u64,
        );
        Proposer {
            id,
            age: AtomicU64::new(0),
            gen: Mutex::new(BallotGenerator::new(id)),
            cfg: RwLock::new(cfg),
            cfg_gen: AtomicU64::new(0),
            transport,
            cache: Mutex::new(RttCache::with_capacity(opts.cache_capacity)),
            lease: Mutex::new(lease),
            clock_epoch: Instant::now(),
            jitter: Mutex::new(Rng::from_entropy()),
            opts,
            metrics: Counters::new(),
        }
    }

    /// Monotonic holder clock for lease windows (µs since construction).
    fn lease_now_us(&self) -> u64 {
        self.clock_epoch.elapsed().as_micros() as u64
    }

    /// This proposer's numeric id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Current identity (id + age) attached to outgoing messages.
    pub fn proposer_id(&self) -> ProposerId {
        ProposerId { id: self.id, age: self.age.load(Ordering::SeqCst) }
    }

    /// The transport this proposer uses (shared with admin tooling).
    pub fn transport(&self) -> Arc<dyn Transport> {
        Arc::clone(&self.transport)
    }

    /// Current cluster configuration (clone).
    pub fn config(&self) -> ClusterConfig {
        self.cfg.read().unwrap().clone()
    }

    /// Installs a new cluster configuration (membership change driver,
    /// §2.3). Clears the 1-RTT cache (cached promises were granted under
    /// the old acceptor set / quorum sizes) and **revokes held read
    /// leases** first — local serving stops before the release goes
    /// out, so the old acceptors are never left blocking writers for a
    /// holder that moved on.
    pub fn update_config(&self, cfg: ClusterConfig) -> CasResult<()> {
        cfg.validate()?;
        // Clear lease state and swap the config ATOMICALLY under the
        // lease lock (lock order lease → cfg, same as lease_round's
        // install): an in-flight grant round must never observe the old
        // config, then arm its window after this clear.
        let (held, old_cfg) = {
            let mut lease = self.lease.lock().unwrap();
            let held = lease.held_keys();
            lease.clear();
            let mut cur = self.cfg.write().unwrap();
            let old = cur.clone();
            *cur = cfg;
            self.cfg_gen.fetch_add(1, Ordering::SeqCst);
            (held, old)
        };
        if !held.is_empty() {
            self.revoke_leases(&held, &old_cfg);
        }
        self.cache.lock().unwrap().clear();
        Ok(())
    }

    /// Best-effort `LeaseRevoke` fan-out for `keys` (explicit lease
    /// break on membership change / failed partial acquisition). Safe
    /// to lose: an undelivered revoke just lets the lease time out.
    fn revoke_leases(&self, keys: &[Key], cfg: &ClusterConfig) {
        let from = self.proposer_id();
        let msgs: Vec<(u64, Request)> = keys
            .iter()
            .flat_map(|key| {
                cfg.acceptors
                    .iter()
                    .map(|&to| (to, Request::LeaseRevoke { key: key.clone(), from }))
                    .collect::<Vec<_>>()
            })
            .collect();
        let (tx, _rx) = mpsc::channel();
        self.transport.fan_out(0, msgs, &tx);
    }

    /// GC step 2b (§3.1): invalidate the cache and lease entries for
    /// `key`, fast-forward the ballot counter past `min_counter`, bump
    /// the age. Returns the new age.
    pub fn gc_sync(&self, key: &Key, min_counter: u64) -> u64 {
        self.cache.lock().unwrap().invalidate(key);
        if self.lease.lock().unwrap().invalidate(key) {
            self.metrics.lease_break.fetch_add(1, Ordering::Relaxed);
        }
        self.gen.lock().unwrap().fast_forward(Ballot::new(min_counter, 0));
        self.age.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Applies `change` to register `key`, retrying on conflicts with
    /// fast-forwarded ballots. Returns the resulting state.
    ///
    /// For a rejected conditional change (stale [`ChangeFn::Cas`]) this
    /// returns [`CasError::Rejected`]; use [`Proposer::change_detailed`]
    /// to also observe the current state in that case.
    pub fn change(&self, key: impl Into<Key>, change: ChangeFn) -> CasResult<Val> {
        let out = self.change_detailed(key, change)?;
        if out.accepted {
            Ok(out.state)
        } else {
            Err(CasError::Rejected(format!("current state is {}", out.state)))
        }
    }

    /// Like [`Proposer::change`] but exposes the full round outcome.
    pub fn change_detailed(
        &self,
        key: impl Into<Key>,
        change: ChangeFn,
    ) -> CasResult<RoundOutcome> {
        self.shed_if_overloaded()?;
        let key: Key = key.into();
        if self.opts.read_mode != ReadMode::Lease {
            return self.change_rounds(&key, change);
        }
        // Lease mode: bracket the write so a concurrent grant round
        // can't arm a value whose snapshots missed this write's commit,
        // and keep the 0-RTT value in step with the outcome.
        self.lease.lock().unwrap().write_started(&key);
        let result = self.change_rounds(&key, change);
        let now = self.lease_now_us();
        let mut lease = self.lease.lock().unwrap();
        match &result {
            Ok(out) => {
                // Committed: the outcome is known and, inside a live
                // lease, IS the register's current value.
                lease.write_finished(&key, now, true);
                lease.note_write(&key, out.state.clone(), now);
            }
            Err(_) => {
                // Unknown outcome (a conflicted/timed-out accept may
                // still land): poison value installs for the straggler
                // horizon and stop serving locally.
                lease.write_finished(&key, now, false);
                if lease.invalidate(&key) {
                    self.metrics.lease_break.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        drop(lease);
        result
    }

    /// The retry loop behind [`Proposer::change_detailed`].
    fn change_rounds(&self, key: &Key, change: ChangeFn) -> CasResult<RoundOutcome> {
        let mut last_err = CasError::RetriesExhausted { attempts: 0 };
        for attempt in 0..self.opts.max_attempts {
            if attempt > 0 {
                self.metrics.retries.fetch_add(1, Ordering::Relaxed);
                self.backoff(attempt);
            }
            self.metrics.rounds.fetch_add(1, Ordering::Relaxed);
            let (core, msgs) = self.build_round(key, change.clone());
            match self.run_round(core, msgs) {
                Ok(out) => {
                    if self.opts.piggyback {
                        if let Some(next) = out.next_promised {
                            // Keep the generator ahead of promised ballots
                            // so a cache miss can't reuse a burned number.
                            self.gen.lock().unwrap().fast_forward(next);
                            self.cache.lock().unwrap().put(key.clone(), next, out.state.clone());
                        }
                    }
                    self.metrics.commits.fetch_add(1, Ordering::Relaxed);
                    return Ok(out);
                }
                Err(CasError::Conflict(seen)) => {
                    self.metrics.conflicts.fetch_add(1, Ordering::Relaxed);
                    self.gen.lock().unwrap().fast_forward(seen);
                    self.cache.lock().unwrap().invalidate(key);
                    last_err = CasError::Conflict(seen);
                }
                Err(e @ CasError::StaleAge { .. }) => {
                    // The deletion GC fenced this proposer (§3.1); it must
                    // be re-synced via gc_sync, not silently self-healed.
                    self.metrics.failures.fetch_add(1, Ordering::Relaxed);
                    return Err(e);
                }
                Err(e) => {
                    self.cache.lock().unwrap().invalidate(key);
                    last_err = e;
                }
            }
        }
        self.metrics.failures.fetch_add(1, Ordering::Relaxed);
        Err(match last_err {
            CasError::Conflict(b) => CasError::Conflict(b),
            _ => CasError::RetriesExhausted { attempts: self.opts.max_attempts },
        })
    }

    fn build_round(&self, key: &Key, change: ChangeFn) -> (RoundCore, Vec<(u64, Request)>) {
        let cfg = self.cfg.read().unwrap().clone();
        let from = self.proposer_id();
        if self.opts.piggyback {
            if let Some(entry) = self.cache.lock().unwrap().take(key) {
                self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                return RoundCore::new_cached(
                    key.clone(),
                    change,
                    entry.ballot,
                    entry.val,
                    from,
                    cfg,
                    true,
                );
            }
        }
        let ballot = self.gen.lock().unwrap().next();
        RoundCore::new(key.clone(), change, ballot, from, cfg, self.opts.piggyback)
    }

    fn run_round(&self, mut core: RoundCore, msgs: Vec<(u64, Request)>) -> CasResult<RoundOutcome> {
        let (tx, rx) = mpsc::channel();
        self.transport.fan_out(core.token(), msgs, &tx);
        let deadline = Instant::now() + self.opts.round_timeout;
        loop {
            let now = Instant::now();
            if now >= deadline {
                // Ask the core, which knows the phase and the real
                // ok-count — a hardcoded `got: 0` here made a slow
                // straggler indistinguishable from a dead cluster.
                return Err(core.timeout_error());
            }
            match rx.recv_timeout(deadline - now) {
                Ok(reply) => match core.on_reply(reply.token, reply.from, reply.resp) {
                    Step::Continue => {}
                    Step::Send(more) => self.transport.fan_out(core.token(), more, &tx),
                    Step::Done(res) => return res,
                },
                Err(_) => return Err(core.timeout_error()),
            }
        }
    }

    fn backoff(&self, attempt: u32) {
        let exp = self.opts.backoff.as_micros() as u64 * (1u64 << attempt.min(10));
        let jitter = self.jitter.lock().unwrap().gen_range(exp + 1);
        std::thread::sleep(Duration::from_micros(exp + jitter));
    }

    // ---- convenience API (the §2.2 specializations) ----

    /// Linearizable read.
    ///
    /// In [`ReadMode::Quorum`] (the default) this first attempts the
    /// **1-RTT fast path**: one `Read` fan-out, served immediately when
    /// a read quorum reports a matching stable state — one round trip,
    /// zero acceptor writes, zero fsyncs. When the quorum disagrees or
    /// another proposer's write is in flight it falls back to the
    /// classic identity-CAS round ([`Proposer::get_via_cas`]), so the
    /// result is linearizable either way. Per-path counters:
    /// [`Counters::read_fast`](crate::metrics::Counters) /
    /// `read_fallback`.
    pub fn get(&self, key: impl Into<Key>) -> CasResult<Val> {
        let key: Key = key.into();
        match self.opts.read_mode {
            ReadMode::Cas => return self.get_via_cas(key),
            ReadMode::Lease => return self.get_via_lease(key),
            ReadMode::Quorum => {}
        }
        // The backpressure gate sits just before actual fan-out — NOT
        // at the top of `get`, where it would also shed lease-covered
        // 0-RTT reads that send nothing (the Cas/Lease arms gate their
        // own fan-outs).
        self.shed_if_overloaded()?;
        match self.quorum_read(&key) {
            Ok(Some(v)) => {
                self.metrics.read_fast.fetch_add(1, Ordering::Relaxed);
                Ok(v)
            }
            Ok(None) => {
                self.metrics.read_fallback.fetch_add(1, Ordering::Relaxed);
                self.get_via_cas(key)
            }
            Err(e) => {
                // Hard failure (GC age fence): count it like the
                // classic path does.
                self.metrics.failures.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// [`ReadMode::Lease`] read: serve 0-RTT from lease-covered local
    /// state when possible; otherwise run a grant round (which doubles
    /// as a 1-RTT read); otherwise fall back to the identity-CAS round.
    fn get_via_lease(&self, key: Key) -> CasResult<Val> {
        let now = self.lease_now_us();
        match self.lease.lock().unwrap().local_read(&key, now) {
            LeaseRead::Hit(v) => {
                // ZERO transport sends: the whole read is this lookup —
                // it keeps serving even when the transport is saturated
                // (there is no fan-out to shed).
                self.metrics.read_lease.fetch_add(1, Ordering::Relaxed);
                return Ok(v);
            }
            // Renew cadence: inside the margin a read pays 1 RTT (the
            // grant round below) so later reads stay 0-RTT; a failed
            // renewal drops to the classic fallback.
            LeaseRead::NeedsRenew | LeaseRead::Miss => {}
            LeaseRead::Expired => {
                self.metrics.lease_break.fetch_add(1, Ordering::Relaxed);
            }
        }
        // Everything below fans out: the backpressure gate applies from
        // here (the CAS fallback re-gates itself in change_detailed).
        self.shed_if_overloaded()?;
        if let Some(v) = self.lease_round(&key).value {
            return Ok(v);
        }
        self.metrics.read_fallback.fetch_add(1, Ordering::Relaxed);
        self.get_via_cas(key)
    }

    /// Redirect-aware read for a routing tier ([`crate::router`]). In
    /// [`ReadMode::Lease`], when the grant round is denied and the
    /// denial names a FOREIGN leaseholder, this returns
    /// [`RoutedRead::Redirect`] instead of grinding through the fenced
    /// identity-CAS path (which conflicts until the holder's
    /// skew-bounded window lapses): the router re-issues the read on
    /// the holder, which serves it 0-RTT from local state. Non-lease
    /// modes never redirect, and neither does a denial naming this
    /// proposer itself (the contested-renewal case).
    pub fn get_or_redirect(&self, key: impl Into<Key>) -> CasResult<RoutedRead> {
        let key: Key = key.into();
        if self.opts.read_mode != ReadMode::Lease {
            return self.get(key).map(RoutedRead::Val);
        }
        let now = self.lease_now_us();
        match self.lease.lock().unwrap().local_read(&key, now) {
            LeaseRead::Hit(v) => {
                self.metrics.read_lease.fetch_add(1, Ordering::Relaxed);
                return Ok(RoutedRead::Val(v));
            }
            LeaseRead::NeedsRenew | LeaseRead::Miss => {}
            LeaseRead::Expired => {
                self.metrics.lease_break.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.shed_if_overloaded()?;
        let attempt = self.lease_round(&key);
        if let Some(v) = attempt.value {
            return Ok(RoutedRead::Val(v));
        }
        match attempt.holder {
            // A foreign holder was named: hand the read over rather
            // than waiting out the lease window on the fenced path.
            Some(h) if h != self.id => Ok(RoutedRead::Redirect { holder: h }),
            _ => {
                self.metrics.read_fallback.fetch_add(1, Ordering::Relaxed);
                self.get_via_cas(key).map(RoutedRead::Val)
            }
        }
    }

    /// Background renewal tick: re-runs the grant round for every held
    /// lease whose serving window ends within `horizon` of now (see
    /// [`LeaseCore::keys_expiring_within`]), so hot keys stay
    /// 0-RTT-covered across read gaps instead of breaking on the first
    /// read after a lull. Returns the number of keys renewed. Skips
    /// the whole tick when the transport is saturated — renewal is an
    /// optimization and must not pile onto a struggling connection.
    pub fn renew_due_leases(&self, horizon: Duration) -> usize {
        if self.opts.read_mode != ReadMode::Lease || self.shed_if_overloaded().is_err() {
            return 0;
        }
        let now = self.lease_now_us();
        let due = self
            .lease
            .lock()
            .unwrap()
            .keys_expiring_within(now, horizon.as_micros() as u64);
        for key in &due {
            self.lease_round(key);
        }
        due.len()
    }

    /// One lease acquire/renew fan-out. Yields the read value when the
    /// grant snapshots agree (1 RTT); arms the 0-RTT window when every
    /// acceptor granted; revokes partial grant sets so a half-acquired
    /// lease never blocks rival writers for the full duration. On a
    /// denial the attempt carries the leaseholder the denying acceptor
    /// named — the redirect target for [`Proposer::get_or_redirect`].
    fn lease_round(&self, key: &Key) -> LeaseAttempt {
        let now_us = self.lease_now_us();
        // Capture config + generation and begin the round atomically
        // w.r.t. update_config (which mutates both under the lease
        // lock; lock order lease → cfg everywhere).
        let (mut round, msgs, cfg, begun_gen) = {
            let lease = self.lease.lock().unwrap();
            let cfg = self.cfg.read().unwrap().clone();
            let begun_gen = self.cfg_gen.load(Ordering::SeqCst);
            let (round, msgs) = lease.begin(key, now_us, self.proposer_id(), &cfg);
            (round, msgs, cfg, begun_gen)
        };
        let (tx, rx) = mpsc::channel();
        self.transport.fan_out(0, msgs, &tx);
        let deadline = Instant::now() + self.opts.round_timeout;
        let outcome = loop {
            let now = Instant::now();
            if now >= deadline {
                break round.outcome();
            }
            match rx.recv_timeout(deadline - now) {
                Ok(reply) => match round.on_reply(reply.from, reply.resp) {
                    LeaseStep::Continue => {}
                    LeaseStep::Done(outcome) => break outcome,
                },
                Err(_) => break round.outcome(),
            }
        };
        // A config change (even an idempotent re-push — it already
        // revoked acceptor-side leases) may have landed while the
        // round was in flight: its grants must neither arm a window
        // nor serve a value. The generation check runs under the lease
        // lock — update_config bumps the generation under the same
        // lock, so a stale install cannot interleave with its clear().
        let (armed, cfg_unchanged) = {
            let mut lease = self.lease.lock().unwrap();
            let unchanged = self.cfg_gen.load(Ordering::SeqCst) == begun_gen;
            let armed = if unchanged {
                lease.install(key, &outcome)
            } else {
                lease.invalidate(key);
                false
            };
            (armed, unchanged)
        };
        if armed {
            self.metrics.lease_renew.fetch_add(1, Ordering::Relaxed);
        } else if outcome.grants > 0 {
            // Drop whatever subset did grant: leaving a partial set
            // in place would stall rival writers without buying us the
            // fast path. (Right for the config-raced case too: the
            // grants live on the OLD acceptors in `cfg`.) All-denied
            // rounds skip this — there is nothing to release.
            self.revoke_leases(std::slice::from_ref(key), &cfg);
        }
        if cfg_unchanged {
            LeaseAttempt { value: outcome.value, holder: outcome.holder }
        } else {
            // Re-read (and re-resolve any holder) under the new config.
            LeaseAttempt { value: None, holder: None }
        }
    }

    /// 0-RTT lease-window probe for the server-edge read coalescer: a
    /// pure local lookup that serves ONLY a live lease hit — it never
    /// takes a round, never renews, and never fences, so a miss costs
    /// one mutex lock and nothing on the wire. `None` in non-lease
    /// modes and on `NeedsRenew`/`Miss`/`Expired` (the caller decides
    /// whether to coalesce the quorum read or take the redirect-aware
    /// path, both of which handle renewal).
    pub fn lease_probe(&self, key: &Key) -> Option<Val> {
        if self.opts.read_mode != ReadMode::Lease {
            return None;
        }
        let now = self.lease_now_us();
        match self.lease.lock().unwrap().local_read(key, now) {
            LeaseRead::Hit(v) => {
                self.metrics.read_lease.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            // `local_read` drops the expired entry, so the follow-up
            // read only sees a Miss — count the break here, exactly as
            // the non-probe lease paths do.
            LeaseRead::Expired => {
                self.metrics.lease_break.fetch_add(1, Ordering::Relaxed);
                None
            }
            LeaseRead::NeedsRenew | LeaseRead::Miss => None,
        }
    }

    /// The configured read mode.
    pub fn read_mode(&self) -> ReadMode {
        self.opts.read_mode
    }

    /// (0-RTT lease reads, grant/renew rounds armed, lease breaks).
    pub fn lease_stats(&self) -> (u64, u64, u64) {
        (
            self.metrics.read_lease.load(Ordering::Relaxed),
            self.metrics.lease_renew.load(Ordering::Relaxed),
            self.metrics.lease_break.load(Ordering::Relaxed),
        )
    }

    /// Number of keys with live local lease state.
    pub fn leased_keys(&self) -> usize {
        self.lease.lock().unwrap().len()
    }

    /// Linearizable read via the classic identity transition `x -> x`
    /// (§2.2): a full round with durable acceptor writes. The fallback
    /// of [`Proposer::get`] and the `ReadMode::Cas` implementation.
    pub fn get_via_cas(&self, key: impl Into<Key>) -> CasResult<Val> {
        Ok(self.change_detailed(key, ChangeFn::Read)?.state)
    }

    /// One quorum-read attempt. `Ok(Some(v))` = fast path served;
    /// `Ok(None)` = fall back to the identity-CAS round; `Err` = hard
    /// failure (GC age fence).
    fn quorum_read(&self, key: &Key) -> CasResult<Option<Val>> {
        let cfg = self.cfg.read().unwrap().clone();
        let (mut core, msgs) = ReadCore::new(key.clone(), self.proposer_id(), cfg);
        let (tx, rx) = mpsc::channel();
        self.transport.fan_out(0, msgs, &tx);
        let deadline = Instant::now() + self.opts.round_timeout;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return Ok(None); // timed out: let the classic round try
            }
            match rx.recv_timeout(deadline - now) {
                Ok(reply) => match core.on_reply(reply.from, reply.resp) {
                    ReadStep::Continue => {}
                    ReadStep::Done(Ok(v)) => return Ok(Some(v)),
                    ReadStep::Done(Err(e)) => return Err(e),
                    ReadStep::Fallback => return Ok(None),
                },
                Err(_) => return Ok(None),
            }
        }
    }

    /// Initialize-if-empty (the Synod specialization).
    pub fn init(&self, key: impl Into<Key>, val: i64) -> CasResult<Val> {
        self.change(key, ChangeFn::InitIfEmpty(val))
    }

    /// Unconditional versioned overwrite.
    pub fn set(&self, key: impl Into<Key>, val: i64) -> CasResult<Val> {
        self.change(key, ChangeFn::Set(val))
    }

    /// Compare-and-swap on the version counter.
    pub fn cas(&self, key: impl Into<Key>, expect: i64, val: i64) -> CasResult<Val> {
        self.change(key, ChangeFn::Cas { expect, val })
    }

    /// Atomic increment (the §3.2 read-modify-write collapsed to 1 round).
    pub fn add(&self, key: impl Into<Key>, delta: i64) -> CasResult<Val> {
        self.change(key, ChangeFn::Add(delta))
    }

    /// Writes the deletion tombstone (§3.1 step 1). The actual space
    /// reclamation is the GC's job — see [`crate::gc`].
    pub fn delete(&self, key: impl Into<Key>) -> CasResult<Val> {
        self.change(key, ChangeFn::Tombstone)
    }

    /// (hits, misses) of the 1-RTT cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.lock().unwrap().stats()
    }

    /// Number of keys currently cached (1-RTT).
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Entries evicted from the 1-RTT cache by its capacity cap.
    pub fn cache_evictions(&self) -> u64 {
        self.cache.lock().unwrap().evictions()
    }

    /// (fast-path reads, fallback reads) served by [`Proposer::get`].
    pub fn read_stats(&self) -> (u64, u64) {
        (
            self.metrics.read_fast.load(Ordering::Relaxed),
            self.metrics.read_fallback.load(Ordering::Relaxed),
        )
    }

    /// In-flight request depth on this proposer's transport (`None`
    /// when the transport doesn't track one — in-process transports
    /// complete synchronously). The backpressure gauge: it rises while
    /// an acceptor stalls and drains as replies land or the transport's
    /// timeout sweep expires the stuck requests. Callers shedding load
    /// should throttle new rounds when this climbs, instead of piling
    /// more requests onto a struggling connection.
    pub fn transport_inflight(&self) -> Option<usize> {
        self.transport.inflight()
    }

    /// Backpressure gate consulted before any fan-out: sheds with
    /// [`CasError::Overloaded`] when [`ProposerOpts::max_inflight`] is
    /// set and the transport already reports that many requests
    /// awaiting replies. The condition is self-clearing — the TCP
    /// timeout sweeper fails stuck requests and empties the pending
    /// maps even if the acceptors never answer.
    fn shed_if_overloaded(&self) -> CasResult<()> {
        let max = self.opts.max_inflight;
        if max == 0 {
            return Ok(());
        }
        if let Some(inflight) = self.transport.inflight() {
            if inflight >= max {
                return Err(CasError::Overloaded { inflight, max });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::mem::MemTransport;

    fn cluster(n: usize) -> (Arc<MemTransport>, ClusterConfig) {
        let t = Arc::new(MemTransport::new(n));
        let cfg = ClusterConfig::majority(1, t.acceptor_ids());
        (t, cfg)
    }

    #[test]
    fn set_then_get() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t);
        assert_eq!(p.set("k", 42).unwrap().as_num(), Some(42));
        assert_eq!(p.get("k").unwrap().as_num(), Some(42));
        assert_eq!(p.get("missing").unwrap(), Val::Empty);
    }

    #[test]
    fn add_accumulates() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t);
        for _ in 0..10 {
            p.add("ctr", 1).unwrap();
        }
        assert_eq!(p.get("ctr").unwrap().as_num(), Some(10));
    }

    #[test]
    fn cas_success_and_reject() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t);
        p.set("k", 1).unwrap(); // ver 0
        let v = p.cas("k", 0, 2).unwrap();
        assert_eq!(v, Val::Num { ver: 1, num: 2 });
        match p.cas("k", 0, 3) {
            Err(CasError::Rejected(_)) => {}
            r => panic!("stale CAS must reject, got {r:?}"),
        }
        assert_eq!(p.get("k").unwrap().as_num(), Some(2));
    }

    #[test]
    fn two_proposers_interleave_safely() {
        let (t, cfg) = cluster(3);
        let p1 = Proposer::new(1, cfg.clone(), t.clone());
        let p2 = Proposer::new(2, cfg, t);
        p1.add("k", 1).unwrap();
        p2.add("k", 10).unwrap();
        p1.add("k", 100).unwrap();
        assert_eq!(p2.get("k").unwrap().as_num(), Some(111));
    }

    #[test]
    fn one_rtt_cache_hits_on_repeat_writes() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t.clone());
        for i in 0..5 {
            p.add("k", i).unwrap();
        }
        let (hits, _) = p.cache_stats();
        assert!(hits >= 4, "subsequent writes should hit the 1-RTT cache, hits={hits}");
        // 1st round: prepare(3) + accept(3); cached rounds: accept(3).
        assert!(
            t.request_count() <= 6 + 4 * 3,
            "1-RTT should cut request count, got {}",
            t.request_count()
        );
    }

    #[test]
    fn survives_one_acceptor_down() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t.clone());
        t.set_down(3, true);
        assert_eq!(p.set("k", 7).unwrap().as_num(), Some(7));
        assert_eq!(p.get("k").unwrap().as_num(), Some(7));
    }

    #[test]
    fn fails_without_quorum() {
        let (t, cfg) = cluster(3);
        let opts = ProposerOpts {
            max_attempts: 2,
            round_timeout: Duration::from_millis(200),
            ..Default::default()
        };
        let p = Proposer::with_opts(1, cfg, t.clone(), opts);
        t.set_down(2, true);
        t.set_down(3, true);
        assert!(p.set("k", 1).is_err());
    }

    /// Delegates to a [`MemTransport`] but swallows fan-out replies to
    /// the listed acceptors entirely — a stalled connection (no reply
    /// at all), unlike `set_down` (which fails fast with a `None`
    /// reply and lets the round decide quorum-impossible in-round).
    struct StallTransport {
        inner: Arc<MemTransport>,
        stalled: Vec<u64>,
    }

    impl Transport for StallTransport {
        fn send(&self, to: u64, req: &Request) -> CasResult<crate::msg::Response> {
            self.inner.send(to, req)
        }
        fn fan_out(
            &self,
            token: u32,
            msgs: Vec<(u64, Request)>,
            tx: &mpsc::Sender<crate::transport::Reply>,
        ) {
            let kept: Vec<(u64, Request)> =
                msgs.into_iter().filter(|(to, _)| !self.stalled.contains(to)).collect();
            self.inner.fan_out(token, kept, tx);
        }
    }

    #[test]
    fn timeout_after_one_reply_reports_the_real_count() {
        // One promise lands, the other two connections stall (no reply,
        // not even a failure): the timeout error must carry got=1 so
        // operators can tell a slow straggler from a dead cluster.
        let (t, cfg) = cluster(3);
        let stalled = Arc::new(StallTransport { inner: t, stalled: vec![2, 3] });
        let opts =
            ProposerOpts { round_timeout: Duration::from_millis(50), ..Default::default() };
        let p = Proposer::with_opts(1, cfg.clone(), stalled, opts);
        let (core, msgs) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            p.proposer_id(),
            cfg,
            false,
        );
        match p.run_round(core, msgs) {
            Err(CasError::NoQuorum { needed: 2, got: 1 }) => {}
            r => panic!("timeout must report the real promise count, got {r:?}"),
        }
    }

    /// Wraps a [`MemTransport`] but reports a saturated in-flight depth
    /// once armed, as a TCP transport with a stuck connection would.
    struct SaturatedTransport {
        inner: Arc<MemTransport>,
        saturated: std::sync::atomic::AtomicBool,
    }

    impl Transport for SaturatedTransport {
        fn send(&self, to: u64, req: &Request) -> CasResult<crate::msg::Response> {
            self.inner.send(to, req)
        }
        fn fan_out(
            &self,
            token: u32,
            msgs: Vec<(u64, Request)>,
            tx: &mpsc::Sender<crate::transport::Reply>,
        ) {
            self.inner.fan_out(token, msgs, tx);
        }
        fn inflight(&self) -> Option<usize> {
            if self.saturated.load(Ordering::SeqCst) {
                Some(1 << 20)
            } else {
                None
            }
        }
    }

    #[test]
    fn saturated_transport_still_serves_lease_covered_reads() {
        let (t, cfg) = cluster(3);
        let sat = Arc::new(SaturatedTransport {
            inner: t,
            saturated: std::sync::atomic::AtomicBool::new(false),
        });
        let opts = ProposerOpts { max_inflight: 64, ..lease_opts(60_000, 100) };
        let p = Proposer::with_opts(1, cfg, Arc::clone(&sat) as Arc<dyn Transport>, opts);
        p.set("k", 42).unwrap();
        assert_eq!(p.get("k").unwrap().as_num(), Some(42)); // arms the lease
        // Saturate the transport: the lease-covered read performs ZERO
        // fan-outs and must keep serving...
        sat.saturated.store(true, Ordering::SeqCst);
        assert_eq!(p.get("k").unwrap().as_num(), Some(42), "0-RTT read must not be shed");
        // ...while anything that WOULD fan out is shed.
        assert!(matches!(p.get("other"), Err(CasError::Overloaded { .. })));
        assert!(matches!(p.set("k", 43), Err(CasError::Overloaded { .. })));
    }

    #[test]
    fn quorum_read_is_shed_when_saturated() {
        let (t, cfg) = cluster(3);
        let sat = Arc::new(SaturatedTransport {
            inner: t,
            saturated: std::sync::atomic::AtomicBool::new(true),
        });
        let opts = ProposerOpts { max_inflight: 1, ..Default::default() };
        let p = Proposer::with_opts(1, cfg, sat, opts);
        assert!(matches!(p.get("k"), Err(CasError::Overloaded { .. })));
    }

    #[test]
    fn recovers_after_dropped_messages() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t.clone());
        t.drop_next(1, 2);
        t.drop_next(2, 1);
        assert_eq!(p.set("k", 5).unwrap().as_num(), Some(5));
    }

    #[test]
    fn concurrent_adds_count_exactly() {
        let (t, cfg) = cluster(3);
        let mut handles = Vec::new();
        for id in 1..=4u64 {
            let p = Arc::new(Proposer::new(id, cfg.clone(), t.clone()));
            for _ in 0..5 {
                let p = Arc::clone(&p);
                handles.push(std::thread::spawn(move || p.add("ctr", 1).is_ok()));
            }
        }
        let ok = handles.into_iter().filter_map(|h| h.join().ok()).filter(|ok| *ok).count() as i64;
        let reader = Proposer::new(99, cfg, t);
        let total = reader.get("ctr").unwrap().as_num().unwrap();
        assert_eq!(total, ok, "every acknowledged increment is counted exactly once");
        assert!(ok > 0);
    }

    #[test]
    fn mem_transport_reports_no_inflight_depth() {
        // The in-process transport completes sends synchronously:
        // there is no pending map, so no depth gauge to surface.
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t);
        p.set("k", 1).unwrap();
        assert_eq!(p.transport_inflight(), None);
    }

    #[test]
    fn config_update_clears_cache() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg.clone(), t);
        p.set("k", 1).unwrap();
        assert!(p.cache_len() > 0);
        p.update_config(cfg).unwrap();
        assert_eq!(p.cache_len(), 0);
    }

    #[test]
    fn quorum_read_takes_fast_path_on_stable_key() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t.clone());
        p.set("k", 42).unwrap();
        let before = t.request_count();
        assert_eq!(p.get("k").unwrap().as_num(), Some(42));
        let (fast, fallback) = p.read_stats();
        assert_eq!(fast, 1, "same-proposer read of a stable key is fast-path");
        assert_eq!(fallback, 0);
        // ONE phase: exactly one Read per acceptor, zero writes.
        assert_eq!(t.request_count() - before, 3, "1 RTT = 3 requests");
    }

    #[test]
    fn quorum_read_falls_back_on_foreign_promise() {
        let (t, cfg) = cluster(3);
        let writer = Proposer::new(1, cfg.clone(), t.clone());
        writer.set("k", 7).unwrap(); // leaves writer's piggybacked promise
        let reader = Proposer::new(2, cfg, t);
        assert_eq!(reader.get("k").unwrap().as_num(), Some(7));
        let (fast, fallback) = reader.read_stats();
        assert_eq!(fast, 0, "foreign promise in flight must not fast-path");
        assert_eq!(fallback, 1, "must fall back to the identity-CAS round");
    }

    #[test]
    fn quorum_read_fast_path_reads_own_writes() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t);
        for i in 0..5 {
            p.set("k", i).unwrap();
            assert_eq!(p.get("k").unwrap().as_num(), Some(i), "read-your-writes");
        }
        let (fast, _) = p.read_stats();
        assert_eq!(fast, 5, "own piggybacked promise never blocks the fast path");
    }

    #[test]
    fn quorum_read_falls_back_when_replies_disagree() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t.clone());
        // Write lands on 1 and 2 only: acceptor 3 is behind.
        t.set_down(3, true);
        p.set("k", 9).unwrap();
        t.set_down(3, false);
        // Another proposer without cached state reads: acceptor 3
        // disagrees with the quorum... but 1 and 2 still match, and the
        // promise on them belongs to p (foreign!) — fallback either way.
        let reader = Proposer::new(2, cfg, t);
        assert_eq!(reader.get("k").unwrap().as_num(), Some(9), "fallback serves the value");
        let (_, fallback) = reader.read_stats();
        assert_eq!(fallback, 1);
    }

    #[test]
    fn cas_read_mode_skips_fast_path() {
        let (t, cfg) = cluster(3);
        let opts = ProposerOpts { read_mode: ReadMode::Cas, ..Default::default() };
        let p = Proposer::with_opts(1, cfg, t, opts);
        p.set("k", 1).unwrap();
        assert_eq!(p.get("k").unwrap().as_num(), Some(1));
        assert_eq!(p.read_stats(), (0, 0), "Cas mode never touches the read path");
    }

    #[test]
    fn quorum_read_survives_one_acceptor_down() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t.clone());
        p.set("k", 5).unwrap();
        t.set_down(3, true);
        assert_eq!(p.get("k").unwrap().as_num(), Some(5), "majority still reads");
    }

    #[test]
    fn rounds_survive_reordered_replies() {
        // The pipelined TCP transport delivers a fan-out's replies in
        // completion order, not send order; the mem transport's reorder
        // knob models that. Writes, quorum reads and lease rounds must
        // all be insensitive to reply order.
        let (t, cfg) = cluster(3);
        t.reorder_replies(0xD15C0);
        let p = Proposer::new(1, cfg.clone(), t.clone());
        for i in 0..5 {
            p.set("k", i).unwrap();
            assert_eq!(p.get("k").unwrap().as_num(), Some(i), "read-your-writes");
        }
        let (fast, fallback) = p.read_stats();
        assert_eq!(fast + fallback, 5);
        let leased = Proposer::with_opts(2, cfg, t, lease_opts(60_000, 100));
        assert_eq!(leased.get("k").unwrap().as_num(), Some(4), "grant round reordered");
    }

    #[test]
    fn cache_capacity_opt_bounds_cache() {
        let (t, cfg) = cluster(3);
        let opts = ProposerOpts { cache_capacity: 8, ..Default::default() };
        let p = Proposer::with_opts(1, cfg, t, opts);
        for i in 0..50 {
            p.set(format!("k{i}"), i).unwrap();
        }
        assert!(p.cache_len() <= 8, "cache exceeded its cap: {}", p.cache_len());
        assert!(p.cache_evictions() >= 42, "evictions counted");
    }

    fn lease_opts(duration_ms: u64, skew_ms: u64) -> ProposerOpts {
        ProposerOpts {
            read_mode: ReadMode::Lease,
            lease: LeaseOpts {
                duration: Duration::from_millis(duration_ms),
                skew_bound: Duration::from_millis(skew_ms),
                renew_margin: Duration::ZERO,
            },
            ..Default::default()
        }
    }

    #[test]
    fn lease_covered_reads_send_zero_requests() {
        let (t, cfg) = cluster(3);
        let p = Proposer::with_opts(1, cfg, t.clone(), lease_opts(60_000, 100));
        p.set("k", 42).unwrap();
        // First read acquires the lease: exactly one full fan-out.
        let before = t.request_count();
        assert_eq!(p.get("k").unwrap().as_num(), Some(42));
        assert_eq!(t.request_count() - before, 3, "acquire round = 1 RTT to all acceptors");
        // Every subsequent read is 0-RTT: ZERO transport requests.
        let before = t.request_count();
        for _ in 0..50 {
            assert_eq!(p.get("k").unwrap().as_num(), Some(42));
        }
        assert_eq!(t.request_count(), before, "lease-covered reads must not touch the network");
        let (local, renews, breaks) = p.lease_stats();
        assert_eq!(local, 50);
        assert_eq!(renews, 1);
        assert_eq!(breaks, 0);
        assert_eq!(p.leased_keys(), 1);
    }

    #[test]
    fn lease_reads_see_own_writes_without_network() {
        let (t, cfg) = cluster(3);
        let p = Proposer::with_opts(1, cfg, t.clone(), lease_opts(60_000, 100));
        p.set("k", 1).unwrap();
        assert_eq!(p.get("k").unwrap().as_num(), Some(1)); // arms the lease
        for i in 2..6 {
            p.set("k", i).unwrap(); // note_write keeps the local value current
            let before = t.request_count();
            assert_eq!(p.get("k").unwrap().as_num(), Some(i), "read-your-writes");
            assert_eq!(t.request_count(), before, "still 0-RTT after a write");
        }
    }

    #[test]
    fn lease_blocks_foreign_writers_until_expiry() {
        let (t, cfg) = cluster(3);
        let holder = Proposer::with_opts(1, cfg.clone(), t.clone(), lease_opts(40, 5));
        holder.set("k", 7).unwrap();
        assert_eq!(holder.get("k").unwrap().as_num(), Some(7));
        // A rival's write is rejected while the ~40ms window lives, but
        // its retry/backoff schedule outlasts the window: it must
        // eventually commit (a lease can delay writers, never kill them).
        let rival = Proposer::new(2, cfg, t);
        assert_eq!(rival.set("k", 8).unwrap().as_num(), Some(8));
    }

    #[test]
    fn foreign_leaseholder_read_falls_back_but_serves() {
        let (t, cfg) = cluster(3);
        let holder = Proposer::with_opts(1, cfg.clone(), t.clone(), lease_opts(40, 5));
        holder.set("k", 7).unwrap();
        assert_eq!(holder.get("k").unwrap().as_num(), Some(7)); // holder leased
        // Another lease-mode reader is denied the lease but still gets
        // a linearizable answer (grant-round read or CAS fallback).
        let reader = Proposer::with_opts(2, cfg, t, lease_opts(40, 5));
        assert_eq!(reader.get("k").unwrap().as_num(), Some(7));
        assert_eq!(reader.leased_keys(), 0, "denied acquisition must not arm a window");
    }

    #[test]
    fn lease_expiry_breaks_then_reacquires() {
        let (t, cfg) = cluster(3);
        let p = Proposer::with_opts(1, cfg, t, lease_opts(30, 5));
        p.set("k", 1).unwrap();
        assert_eq!(p.get("k").unwrap().as_num(), Some(1));
        std::thread::sleep(Duration::from_millis(40)); // outlive the window
        assert_eq!(p.get("k").unwrap().as_num(), Some(1), "re-acquires after expiry");
        let (_, renews, breaks) = p.lease_stats();
        assert!(breaks >= 1, "expiry must count as a lease break");
        assert!(renews >= 2, "expiry forces a fresh acquisition");
    }

    #[test]
    fn lease_survives_one_acceptor_down_via_fallback() {
        let (t, cfg) = cluster(3);
        let p = Proposer::with_opts(1, cfg, t.clone(), lease_opts(60_000, 100));
        p.set("k", 5).unwrap();
        t.set_down(3, true);
        // The full grant set is unreachable: the 0-RTT window must NOT
        // arm, but the read itself is still served (grant-round value).
        assert_eq!(p.get("k").unwrap().as_num(), Some(5));
        assert_eq!(p.leased_keys(), 0, "partial grant set must not arm");
        assert_eq!(p.get("k").unwrap().as_num(), Some(5), "reads keep working degraded");
    }

    #[test]
    fn update_config_revokes_leases() {
        let (t, cfg) = cluster(3);
        let holder = Proposer::with_opts(1, cfg.clone(), t.clone(), lease_opts(60_000, 100));
        holder.set("k", 7).unwrap();
        assert_eq!(holder.get("k").unwrap().as_num(), Some(7));
        assert_eq!(holder.leased_keys(), 1);
        // Membership change: local state drops AND acceptors release,
        // so a rival writes immediately (no 60s wait).
        holder.update_config(cfg.clone()).unwrap();
        assert_eq!(holder.leased_keys(), 0);
        let rival = Proposer::with_opts(
            2,
            cfg,
            t,
            ProposerOpts { max_attempts: 3, ..Default::default() },
        );
        assert_eq!(rival.set("k", 8).unwrap().as_num(), Some(8), "revoke freed the key");
    }

    #[test]
    fn gc_sync_drops_lease_state() {
        let (t, cfg) = cluster(3);
        let p = Proposer::with_opts(1, cfg, t, lease_opts(60_000, 100));
        p.set("k", 1).unwrap();
        assert_eq!(p.get("k").unwrap().as_num(), Some(1));
        assert_eq!(p.leased_keys(), 1);
        p.gc_sync(&"k".to_string(), 10);
        assert_eq!(p.leased_keys(), 0, "GC sync must stop local serving");
        let (_, _, breaks) = p.lease_stats();
        assert!(breaks >= 1);
    }

    #[test]
    fn gc_sync_bumps_age_and_counter() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t);
        p.set("k", 1).unwrap();
        let age = p.gc_sync(&"k".to_string(), 100);
        assert_eq!(age, 1);
        assert_eq!(p.proposer_id().age, 1);
        assert!(p.gen.lock().unwrap().current().counter >= 100);
    }

    #[test]
    fn denied_read_redirects_to_the_leaseholder() {
        let (t, cfg) = cluster(3);
        let holder = Proposer::with_opts(7, cfg.clone(), t.clone(), lease_opts(60_000, 100));
        holder.set("k", 9).unwrap();
        assert_eq!(holder.get("k").unwrap().as_num(), Some(9)); // holder armed
        assert_eq!(holder.leased_keys(), 1);
        // A denied reader whose round still agrees on a value serves it
        // in that same RTT — cheaper than any redirect.
        let other = Proposer::with_opts(2, cfg, t.clone(), lease_opts(60_000, 100));
        match other.get_or_redirect("k").unwrap() {
            RoutedRead::Val(v) => assert_eq!(v.as_num(), Some(9)),
            r => panic!("an agreed denial round must serve directly, got {r:?}"),
        }
        // A write the holder prepared but never completed leaves a
        // foreign-to-the-rival promise above the accepted ballot: now
        // the denial round is blocked, and instead of grinding through
        // the fenced CAS fallback (which waits out the window) the
        // rival learns WHO holds the lease and hands the read over.
        for a in t.acceptor_ids() {
            t.send(
                a,
                &Request::Prepare {
                    key: "k".into(),
                    ballot: Ballot::new(1_000, 7),
                    from: ProposerId::new(7),
                },
            )
            .unwrap();
        }
        match other.get_or_redirect("k").unwrap() {
            RoutedRead::Redirect { holder: h } => assert_eq!(h, 7),
            r => panic!("expected a redirect to the holder, got {r:?}"),
        }
        assert_eq!(other.leased_keys(), 0, "denied acquisition must not arm a window");
        // The holder itself keeps serving 0-RTT — never a self-redirect.
        match holder.get_or_redirect("k").unwrap() {
            RoutedRead::Val(v) => assert_eq!(v.as_num(), Some(9)),
            r => panic!("the holder must serve locally, got {r:?}"),
        }
    }

    #[test]
    fn get_or_redirect_serves_values_in_quorum_mode() {
        let (t, cfg) = cluster(3);
        let p = Proposer::new(1, cfg, t);
        p.set("k", 3).unwrap();
        match p.get_or_redirect("k").unwrap() {
            RoutedRead::Val(v) => assert_eq!(v.as_num(), Some(3)),
            r => panic!("quorum mode must never redirect, got {r:?}"),
        }
    }

    #[test]
    fn background_renewal_keeps_lease_covered_across_read_gaps() {
        let (t, cfg) = cluster(3);
        // 200ms window, 20ms skew: without renewal, a 240ms read gap
        // would expire the lease and force a break + re-acquire.
        let p = Proposer::with_opts(1, cfg, t.clone(), lease_opts(200, 20));
        p.set("k", 5).unwrap();
        assert_eq!(p.get("k").unwrap().as_num(), Some(5)); // arm
        // Simulated per-shard timer: tick well inside the window with a
        // horizon wide enough to catch the key before it lapses.
        for _ in 0..8 {
            std::thread::sleep(Duration::from_millis(30));
            p.renew_due_leases(Duration::from_millis(120));
        }
        // The gap outlived the original window, but the timer kept the
        // key covered: this read is still 0-RTT and nothing broke.
        let before = t.request_count();
        assert_eq!(p.get("k").unwrap().as_num(), Some(5));
        assert_eq!(t.request_count(), before, "read after the gap must stay 0-RTT");
        let (_, _, breaks) = p.lease_stats();
        assert_eq!(breaks, 0, "no lease break across the read gap");
    }

    #[test]
    fn renew_due_leases_skips_quorum_mode_and_covered_keys() {
        let (t, cfg) = cluster(3);
        let quorum = Proposer::new(1, cfg.clone(), t.clone());
        quorum.set("k", 1).unwrap();
        assert_eq!(quorum.renew_due_leases(Duration::from_millis(100)), 0);
        let leased = Proposer::with_opts(2, cfg, t, lease_opts(60_000, 100));
        leased.set("j", 2).unwrap();
        assert_eq!(leased.get("j").unwrap().as_num(), Some(2));
        // A 60s window with a 1ms horizon: nothing is due.
        assert_eq!(leased.renew_due_leases(Duration::from_millis(1)), 0);
    }
}
