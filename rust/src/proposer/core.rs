//! Sans-IO proposer round state machine (§2.2).
//!
//! One [`RoundCore`] drives a single two-phase (or one-phase, with the
//! §2.2.1 cache) state transition for one register. It is pure: callers
//! feed acceptor replies in and get messages/outcomes out, which lets the
//! exact same protocol logic run under tokio (real transports) and inside
//! the deterministic discrete-event simulator (fault-injection tests and
//! the paper's WAN experiments).

use crate::ballot::Ballot;
use crate::change::ChangeFn;
use crate::error::{CasError, CasResult};
use crate::msg::{Key, ProposerId, Request, Response};
use crate::quorum::ClusterConfig;
use crate::state::Val;

/// Successful outcome of one round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundOutcome {
    /// The state written in the accept phase (the new current state).
    pub state: Val,
    /// Whether the change function accepted the prior state (a stale CAS
    /// sets this to false while `state` carries the unchanged value).
    pub accepted: bool,
    /// Ballot the state was written at.
    pub ballot: Ballot,
    /// Ballot promised via piggyback for the proposer's next round on
    /// this key (1-RTT optimization), confirmed by the accept quorum.
    pub next_promised: Option<Ballot>,
}

/// What the round wants the driver to do next.
#[derive(Debug)]
pub enum Step {
    /// Waiting for more replies; nothing to send.
    Continue,
    /// Send these requests (fan-out), then keep feeding replies.
    Send(Vec<(u64, Request)>),
    /// Round finished.
    Done(CasResult<RoundOutcome>),
}

#[derive(Debug, PartialEq)]
enum Phase {
    Prepare,
    Accept,
    Finished,
}

/// A single CASPaxos round for one key.
pub struct RoundCore {
    key: Key,
    change: ChangeFn,
    ballot: Ballot,
    from: ProposerId,
    cfg: ClusterConfig,
    /// Enable the §2.2.1 piggybacked promise for the next round.
    piggyback: bool,

    phase: Phase,
    /// Incremented on every phase transition; replies carrying a stale
    /// token are ignored (guards against late prepare replies corrupting
    /// accept-phase accounting).
    token: u32,
    // Prepare bookkeeping.
    best: (Ballot, Val),
    prepare_oks: usize,
    // Accept bookkeeping.
    accept_oks: usize,
    outcome: Option<(Val, bool)>,
    // Shared bookkeeping.
    replies: usize,
    max_conflict: Ballot,
    conflicts: usize,
    stale_age: Option<u64>,
}

impl RoundCore {
    /// Starts a full two-phase round. Returns the core and the prepare
    /// fan-out to send.
    pub fn new(
        key: Key,
        change: ChangeFn,
        ballot: Ballot,
        from: ProposerId,
        cfg: ClusterConfig,
        piggyback: bool,
    ) -> (Self, Vec<(u64, Request)>) {
        let msgs = cfg
            .acceptors
            .iter()
            .map(|&to| {
                (to, Request::Prepare { key: key.clone(), ballot, from })
            })
            .collect();
        let core = RoundCore {
            key,
            change,
            ballot,
            from,
            cfg,
            piggyback,
            phase: Phase::Prepare,
            token: 0,
            best: (Ballot::ZERO, Val::Empty),
            prepare_oks: 0,
            accept_oks: 0,
            outcome: None,
            replies: 0,
            max_conflict: Ballot::ZERO,
            conflicts: 0,
            stale_age: None,
        };
        (core, msgs)
    }

    /// Starts a one-round-trip round (§2.2.1): the proposer holds a
    /// quorum-confirmed promise for `ballot` and the cached current state
    /// `cached`, so the prepare phase is skipped entirely.
    pub fn new_cached(
        key: Key,
        change: ChangeFn,
        ballot: Ballot,
        cached: Val,
        from: ProposerId,
        cfg: ClusterConfig,
        piggyback: bool,
    ) -> (Self, Vec<(u64, Request)>) {
        let mut core = RoundCore {
            key,
            change,
            ballot,
            from,
            cfg,
            piggyback,
            phase: Phase::Accept,
            token: 0,
            best: (Ballot::ZERO, Val::Empty),
            prepare_oks: 0,
            accept_oks: 0,
            outcome: None,
            replies: 0,
            max_conflict: Ballot::ZERO,
            conflicts: 0,
            stale_age: None,
        };
        let msgs = core.start_accept(cached);
        (core, msgs)
    }

    /// The ballot this round runs at.
    pub fn ballot(&self) -> Ballot {
        self.ballot
    }

    /// Phase token to attach to in-flight requests: replies must echo it
    /// back into [`RoundCore::on_reply`], where stale tokens are dropped.
    pub fn token(&self) -> u32 {
        self.token
    }

    /// Highest conflicting ballot seen (for fast-forward on retry).
    pub fn max_conflict(&self) -> Ballot {
        self.max_conflict
    }

    fn quorum_impossible(&self, oks: usize, quorum: usize) -> bool {
        let remaining = self.cfg.acceptors.len() - self.replies;
        oks + remaining < quorum
    }

    fn start_accept(&mut self, cur: Val) -> Vec<(u64, Request)> {
        let applied = self.change.apply(&cur);
        self.outcome = Some((applied.next.clone(), applied.accepted));
        self.phase = Phase::Accept;
        self.token += 1;
        self.replies = 0;
        let promise_next =
            if self.piggyback { Some(self.ballot.next_for(self.from.id)) } else { None };
        self.cfg
            .acceptors
            .iter()
            .map(|&to| {
                (
                    to,
                    Request::Accept {
                        key: self.key.clone(),
                        ballot: self.ballot,
                        val: applied.next.clone(),
                        from: self.from,
                        promise_next,
                    },
                )
            })
            .collect()
    }

    fn finish(&mut self, result: CasResult<RoundOutcome>) -> Step {
        self.phase = Phase::Finished;
        Step::Done(result)
    }

    fn fail(&mut self) -> Step {
        let res = if let Some(required) = self.stale_age {
            Err(CasError::StaleAge { required, got: self.from.age })
        } else if self.conflicts > 0 {
            Err(CasError::Conflict(self.max_conflict))
        } else {
            let (needed, got) = match self.phase {
                Phase::Prepare => (self.cfg.quorum.prepare, self.prepare_oks),
                _ => (self.cfg.quorum.accept, self.accept_oks),
            };
            Err(CasError::NoQuorum { needed, got })
        };
        self.finish(res)
    }

    /// Feeds one acceptor reply (or a transport failure as `None`).
    /// `token` must be the value of [`RoundCore::token`] at the time the
    /// corresponding request was sent; stale-phase replies are dropped.
    pub fn on_reply(&mut self, token: u32, _from: u64, resp: Option<Response>) -> Step {
        if self.phase == Phase::Finished || token != self.token {
            return Step::Continue; // late/stale reply: ignore
        }
        self.replies += 1;
        match resp {
            Some(Response::Conflict { seen }) => {
                self.conflicts += 1;
                self.max_conflict = self.max_conflict.max(seen);
            }
            Some(Response::StaleAge { required }) => {
                self.stale_age = Some(self.stale_age.unwrap_or(0).max(required));
            }
            Some(Response::Promise { accepted_ballot, accepted_val })
                if self.phase == Phase::Prepare =>
            {
                self.prepare_oks += 1;
                // "picks the value of the tuple with the highest ballot".
                if accepted_ballot >= self.best.0 {
                    self.best = (accepted_ballot, accepted_val);
                }
            }
            Some(Response::Accepted) if self.phase == Phase::Accept => {
                self.accept_oks += 1;
            }
            // Transport failure, Error response, or a phase-mismatched
            // reply (e.g. a promise arriving after we moved to accept —
            // impossible per driver contract, but harmless): counts only
            // toward `replies`.
            _ => {}
        }

        match self.phase {
            Phase::Prepare => {
                if self.prepare_oks >= self.cfg.quorum.prepare {
                    let cur = self.best.1.clone();
                    return Step::Send(self.start_accept(cur));
                }
                if self.stale_age.is_some()
                    || self.quorum_impossible(self.prepare_oks, self.cfg.quorum.prepare)
                {
                    return self.fail();
                }
                Step::Continue
            }
            Phase::Accept => {
                if self.accept_oks >= self.cfg.quorum.accept {
                    let (state, accepted) = self.outcome.clone().expect("accept implies outcome");
                    let next_promised =
                        if self.piggyback { Some(self.ballot.next_for(self.from.id)) } else { None };
                    let ballot = self.ballot;
                    return self.finish(Ok(RoundOutcome { state, accepted, ballot, next_promised }));
                }
                if self.stale_age.is_some()
                    || self.quorum_impossible(self.accept_oks, self.cfg.quorum.accept)
                {
                    return self.fail();
                }
                Step::Continue
            }
            Phase::Finished => Step::Continue,
        }
    }
}

/// What a quorum-read round wants the driver to do next.
#[derive(Debug)]
pub enum ReadStep {
    /// Waiting for more replies.
    Continue,
    /// Fast path decided: `Ok(value)` serves the read after ONE round
    /// trip and ZERO acceptor writes; `Err` is a hard protocol failure
    /// (the GC age fence).
    Done(CasResult<Val>),
    /// The fast path cannot be taken (disagreeing replies, a foreign
    /// promise in flight, or too many failures): the driver must run
    /// the classic identity-CAS round instead. Linearizability is never
    /// weakened — the fallback IS the §2.2 read.
    Fallback,
}

/// Sans-IO quorum-read state machine: one `Read` fan-out, no prepare, no
/// accept, no disk writes on any acceptor.
///
/// The fast path serves value `v` iff `max(prepare, accept)` replies
/// report the identical `(accepted_ballot, value)` pair, that ballot is
/// the highest accepted ballot seen, and no reply carries a *foreign*
/// promise above it. Safety sketch:
///
/// * a set that large intersects every accept quorum, so `v` is chosen
///   and no higher ballot can be chosen without telling one of our
///   replies — the read observes every write that completed before it
///   started;
/// * two quorum reads can never disagree: the second one's reply set
///   intersects whatever accept quorum chose the newer value;
/// * a higher *own* promise (this proposer's piggybacked §2.2.1 ballot)
///   does not block: any in-flight own write either already reached an
///   accept quorum (then it IS the max accepted ballot we match on) or
///   has not completed anywhere and the read linearizes before it.
///
/// A foreign promise above the accepted ballot means another proposer
/// may be mid-write — the conservative answer is the classic round.
pub struct ReadCore {
    from: ProposerId,
    cfg: ClusterConfig,
    replies: usize,
    /// (accepted_ballot, value, promise) per `ReadState` reply.
    states: Vec<(Ballot, Val, Ballot)>,
    finished: bool,
}

impl ReadCore {
    /// Starts a quorum read. Returns the core and the `Read` fan-out.
    pub fn new(key: Key, from: ProposerId, cfg: ClusterConfig) -> (Self, Vec<(u64, Request)>) {
        let msgs = cfg
            .acceptors
            .iter()
            .map(|&to| (to, Request::Read { key: key.clone(), from }))
            .collect();
        (ReadCore { from, cfg, replies: 0, states: Vec::new(), finished: false }, msgs)
    }

    /// Matching replies required to serve the fast path: a set this
    /// large intersects every prepare AND every accept quorum.
    pub fn needed(&self) -> usize {
        self.cfg.quorum.prepare.max(self.cfg.quorum.accept)
    }

    /// Feeds one acceptor reply (or a transport failure as `None`).
    pub fn on_reply(&mut self, _from: u64, resp: Option<Response>) -> ReadStep {
        if self.finished {
            return ReadStep::Continue; // late reply: ignore
        }
        self.replies += 1;
        match resp {
            Some(Response::ReadState { promise, accepted_ballot, accepted_val }) => {
                self.states.push((accepted_ballot, accepted_val, promise));
            }
            Some(Response::StaleAge { required }) => {
                // The GC fenced this proposer; a fallback round would be
                // fenced too, so fail hard like the classic path does.
                self.finished = true;
                return ReadStep::Done(Err(CasError::StaleAge {
                    required,
                    got: self.from.age,
                }));
            }
            // Transport failure or an unexpected response: counts only
            // toward `replies` (and therefore toward exhaustion).
            _ => {}
        }
        self.decide()
    }

    fn decide(&mut self) -> ReadStep {
        if let Some(max_b) = self.states.iter().map(|(b, _, _)| *b).max() {
            let matches = self.states.iter().filter(|(b, _, _)| *b == max_b).count();
            let blocked = self
                .states
                .iter()
                .any(|(_, _, p)| *p > max_b && p.proposer != self.from.id);
            if blocked {
                // A foreign write may be in flight; no later reply can
                // retract a promise, so fall back immediately.
                self.finished = true;
                return ReadStep::Fallback;
            }
            if matches >= self.needed() {
                // A ballot is accepted with exactly one value, so every
                // matching reply carries the same one.
                let val = self
                    .states
                    .iter()
                    .find(|(b, _, _)| *b == max_b)
                    .map(|(_, v, _)| v.clone())
                    .expect("matches >= 1 implies a state at max_b");
                self.finished = true;
                return ReadStep::Done(Ok(val));
            }
        }
        if self.replies >= self.cfg.acceptors.len() {
            // Everyone answered and no stable quorum emerged.
            self.finished = true;
            return ReadStep::Fallback;
        }
        ReadStep::Continue
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg3() -> ClusterConfig {
        ClusterConfig::majority(1, vec![1, 2, 3])
    }

    fn promise_empty() -> Response {
        Response::Promise { accepted_ballot: Ballot::ZERO, accepted_val: Val::Empty }
    }

    #[test]
    fn happy_two_phase_round() {
        let (mut core, msgs) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(7),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        assert_eq!(msgs.len(), 3);
        assert!(matches!(msgs[0].1, Request::Prepare { .. }));

        assert!(matches!(core.on_reply(core.token(), 1, Some(promise_empty())), Step::Continue));
        let accepts = match core.on_reply(core.token(), 2, Some(promise_empty())) {
            Step::Send(m) => m,
            s => panic!("expected accept fan-out, got {s:?}"),
        };
        assert_eq!(accepts.len(), 3);
        assert!(matches!(core.on_reply(core.token(), 1, Some(Response::Accepted)), Step::Continue));
        match core.on_reply(core.token(), 2, Some(Response::Accepted)) {
            Step::Done(Ok(out)) => {
                assert_eq!(out.state.as_num(), Some(7));
                assert!(out.accepted);
                assert_eq!(out.next_promised, None);
            }
            s => panic!("{s:?}"),
        }
        // Late reply ignored.
        assert!(matches!(core.on_reply(core.token(), 3, Some(Response::Accepted)), Step::Continue));
    }

    #[test]
    fn picks_highest_ballot_value() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Add(1),
            Ballot::new(5, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 
            1,
            Some(Response::Promise {
                accepted_ballot: Ballot::new(2, 2),
                accepted_val: Val::Num { ver: 0, num: 10 },
            }),
        );
        let step = core.on_reply(core.token(), 
            2,
            Some(Response::Promise {
                accepted_ballot: Ballot::new(3, 3),
                accepted_val: Val::Num { ver: 1, num: 20 },
            }),
        );
        match step {
            Step::Send(msgs) => match &msgs[0].1 {
                Request::Accept { val, .. } => {
                    assert_eq!(val.as_num(), Some(21), "Add(1) applied to the ballot-3 value")
                }
                r => panic!("{r:?}"),
            },
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn conflict_fails_round_with_max_ballot() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 1, Some(Response::Conflict { seen: Ballot::new(9, 2) }));
        // After two conflicts only one reply remains: quorum of 2 is
        // impossible, so the round fails fast carrying the max ballot.
        match core.on_reply(core.token(), 2, Some(Response::Conflict { seen: Ballot::new(4, 3) })) {
            Step::Done(Err(CasError::Conflict(b))) => assert_eq!(b, Ballot::new(9, 2)),
            s => panic!("{s:?}"),
        }
        // Late reply is ignored.
        assert!(matches!(core.on_reply(core.token(), 3, Some(promise_empty())), Step::Continue));
    }

    #[test]
    fn transport_failures_fail_quorum() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 1, None);
        match core.on_reply(core.token(), 2, None) {
            Step::Done(Err(CasError::NoQuorum { needed: 2, got: 0 })) => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn survives_one_failure_of_three() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 1, None);
        core.on_reply(core.token(), 2, Some(promise_empty()));
        let step = core.on_reply(core.token(), 3, Some(promise_empty()));
        assert!(matches!(step, Step::Send(_)), "quorum reached despite one failure");
    }

    #[test]
    fn cached_round_skips_prepare() {
        let (mut core, msgs) = RoundCore::new_cached(
            "k".into(),
            ChangeFn::Add(5),
            Ballot::new(2, 1),
            Val::Num { ver: 0, num: 10 },
            ProposerId::new(1),
            cfg3(),
            true,
        );
        assert!(matches!(msgs[0].1, Request::Accept { .. }), "no prepare phase");
        match &msgs[0].1 {
            Request::Accept { val, promise_next, .. } => {
                assert_eq!(val.as_num(), Some(15));
                assert_eq!(*promise_next, Some(Ballot::new(3, 1)));
            }
            _ => unreachable!(),
        }
        core.on_reply(core.token(), 1, Some(Response::Accepted));
        match core.on_reply(core.token(), 2, Some(Response::Accepted)) {
            Step::Done(Ok(out)) => {
                assert_eq!(out.state.as_num(), Some(15));
                assert_eq!(out.next_promised, Some(Ballot::new(3, 1)));
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn rejected_cas_still_completes_with_current_state() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Cas { expect: 99, val: 1 },
            Ballot::new(5, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 
            1,
            Some(Response::Promise {
                accepted_ballot: Ballot::new(1, 1),
                accepted_val: Val::Num { ver: 3, num: 42 },
            }),
        );
        let step = core.on_reply(core.token(), 2, Some(promise_empty()));
        let Step::Send(_) = step else { panic!("{step:?}") };
        core.on_reply(core.token(), 1, Some(Response::Accepted));
        match core.on_reply(core.token(), 2, Some(Response::Accepted)) {
            Step::Done(Ok(out)) => {
                assert!(!out.accepted, "stale CAS is rejected");
                assert_eq!(out.state.as_num(), Some(42), "current state returned");
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn stale_age_aborts() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Read,
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        // A single StaleAge aborts immediately: the GC fenced this
        // proposer and no quorum outcome can be trusted.
        match core.on_reply(core.token(), 1, Some(Response::StaleAge { required: 3 })) {
            Step::Done(Err(CasError::StaleAge { required: 3, got: 0 })) => {}
            s => panic!("{s:?}"),
        }
    }

    fn read_state(c: u64, p: u64, num: i64, promise: Ballot) -> Response {
        Response::ReadState {
            promise,
            accepted_ballot: Ballot::new(c, p),
            accepted_val: Val::Num { ver: 0, num },
        }
    }

    #[test]
    fn quorum_read_serves_matching_quorum_in_one_round() {
        let (mut core, msgs) =
            ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        assert_eq!(msgs.len(), 3);
        assert!(matches!(msgs[0].1, Request::Read { .. }));
        assert!(matches!(
            core.on_reply(1, Some(read_state(4, 1, 42, Ballot::ZERO))),
            ReadStep::Continue
        ));
        match core.on_reply(2, Some(read_state(4, 1, 42, Ballot::ZERO))) {
            ReadStep::Done(Ok(v)) => assert_eq!(v.as_num(), Some(42)),
            s => panic!("expected fast-path read, got {s:?}"),
        }
        // Late reply ignored.
        assert!(matches!(
            core.on_reply(3, Some(read_state(4, 1, 42, Ballot::ZERO))),
            ReadStep::Continue
        ));
    }

    #[test]
    fn quorum_read_of_absent_key_serves_empty() {
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        let empty = || Response::ReadState {
            promise: Ballot::ZERO,
            accepted_ballot: Ballot::ZERO,
            accepted_val: Val::Empty,
        };
        core.on_reply(1, Some(empty()));
        match core.on_reply(2, Some(empty())) {
            ReadStep::Done(Ok(v)) => assert!(v.is_empty()),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_falls_back_on_disagreeing_replies() {
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        core.on_reply(1, Some(read_state(4, 1, 42, Ballot::ZERO)));
        core.on_reply(2, Some(read_state(5, 2, 43, Ballot::ZERO)));
        // All three answered, max ballot has only one vote: fallback.
        match core.on_reply(3, Some(read_state(4, 1, 42, Ballot::ZERO))) {
            ReadStep::Fallback => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_falls_back_on_foreign_promise() {
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        // Acceptor 1 promised ballot (7, 2) to ANOTHER proposer: a write
        // may be in flight — immediate fallback.
        match core.on_reply(1, Some(read_state(4, 1, 42, Ballot::new(7, 2)))) {
            ReadStep::Fallback => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_ignores_own_piggybacked_promise() {
        // Proposer 9 reads a key it also writes: acceptors hold its own
        // §2.2.1 piggybacked promise. That must NOT force a fallback.
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        core.on_reply(1, Some(read_state(4, 9, 42, Ballot::new(5, 9))));
        match core.on_reply(2, Some(read_state(4, 9, 42, Ballot::new(5, 9)))) {
            ReadStep::Done(Ok(v)) => assert_eq!(v.as_num(), Some(42)),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_newer_accepted_wins_the_match() {
        // One acceptor is ahead: its ballot is the max, so the stale
        // pair can never satisfy the fast path.
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        core.on_reply(1, Some(read_state(9, 2, 99, Ballot::ZERO)));
        core.on_reply(2, Some(read_state(4, 1, 42, Ballot::ZERO)));
        match core.on_reply(3, Some(read_state(9, 2, 99, Ballot::ZERO))) {
            ReadStep::Done(Ok(v)) => {
                assert_eq!(v.as_num(), Some(99), "must serve the NEWER committed value")
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_transport_failures_force_fallback() {
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        core.on_reply(1, None);
        core.on_reply(2, Some(read_state(4, 1, 42, Ballot::ZERO)));
        match core.on_reply(3, None) {
            ReadStep::Fallback => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_stale_age_fails_hard() {
        let (mut core, _) =
            ReadCore::new("k".into(), ProposerId { id: 9, age: 1 }, cfg3());
        match core.on_reply(1, Some(Response::StaleAge { required: 3 })) {
            ReadStep::Done(Err(CasError::StaleAge { required: 3, got: 1 })) => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_respects_flexible_quorums() {
        // 4 nodes, prepare=2, accept=3: the read quorum is max(2,3)=3.
        let cfg = ClusterConfig {
            epoch: 1,
            acceptors: vec![1, 2, 3, 4],
            quorum: crate::quorum::QuorumSpec::flexible(4, 2, 3).unwrap(),
        };
        let (mut core, msgs) = ReadCore::new("k".into(), ProposerId::new(9), cfg);
        assert_eq!(msgs.len(), 4);
        assert_eq!(core.needed(), 3);
        core.on_reply(1, Some(read_state(4, 1, 42, Ballot::ZERO)));
        assert!(matches!(
            core.on_reply(2, Some(read_state(4, 1, 42, Ballot::ZERO))),
            ReadStep::Continue
        ));
        assert!(matches!(
            core.on_reply(3, Some(read_state(4, 1, 42, Ballot::ZERO))),
            ReadStep::Done(Ok(_))
        ));
    }

    #[test]
    fn flexible_quorum_respected() {
        // paper §2.3: 4 nodes, prepare=2, accept=3
        let cfg = ClusterConfig {
            epoch: 1,
            acceptors: vec![1, 2, 3, 4],
            quorum: crate::quorum::QuorumSpec::flexible(4, 2, 3).unwrap(),
        };
        let (mut core, msgs) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg,
            false,
        );
        assert_eq!(msgs.len(), 4);
        core.on_reply(core.token(), 1, Some(promise_empty()));
        let Step::Send(_) = core.on_reply(core.token(), 2, Some(promise_empty())) else {
            panic!("prepare quorum of 2")
        };
        core.on_reply(core.token(), 1, Some(Response::Accepted));
        core.on_reply(core.token(), 2, Some(Response::Accepted));
        assert!(matches!(core.on_reply(core.token(), 3, Some(Response::Accepted)), Step::Done(Ok(_))));
    }
}
