//! Sans-IO proposer round state machine (§2.2).
//!
//! One [`RoundCore`] drives a single two-phase (or one-phase, with the
//! §2.2.1 cache) state transition for one register. It is pure: callers
//! feed acceptor replies in and get messages/outcomes out, which lets the
//! exact same protocol logic run under tokio (real transports) and inside
//! the deterministic discrete-event simulator (fault-injection tests and
//! the paper's WAN experiments).

use crate::ballot::Ballot;
use crate::change::ChangeFn;
use crate::error::{CasError, CasResult};
use crate::msg::{Key, ProposerId, Request, Response};
use crate::quorum::ClusterConfig;
use crate::state::Val;

/// Successful outcome of one round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundOutcome {
    /// The state written in the accept phase (the new current state).
    pub state: Val,
    /// Whether the change function accepted the prior state (a stale CAS
    /// sets this to false while `state` carries the unchanged value).
    pub accepted: bool,
    /// Ballot the state was written at.
    pub ballot: Ballot,
    /// Ballot promised via piggyback for the proposer's next round on
    /// this key (1-RTT optimization), confirmed by the accept quorum.
    pub next_promised: Option<Ballot>,
}

/// What the round wants the driver to do next.
#[derive(Debug)]
pub enum Step {
    /// Waiting for more replies; nothing to send.
    Continue,
    /// Send these requests (fan-out), then keep feeding replies.
    Send(Vec<(u64, Request)>),
    /// Round finished.
    Done(CasResult<RoundOutcome>),
}

#[derive(Debug, PartialEq)]
enum Phase {
    Prepare,
    Accept,
    Finished,
}

/// A single CASPaxos round for one key.
pub struct RoundCore {
    key: Key,
    change: ChangeFn,
    ballot: Ballot,
    from: ProposerId,
    cfg: ClusterConfig,
    /// Enable the §2.2.1 piggybacked promise for the next round.
    piggyback: bool,

    phase: Phase,
    /// Incremented on every phase transition; replies carrying a stale
    /// token are ignored (guards against late prepare replies corrupting
    /// accept-phase accounting).
    token: u32,
    // Prepare bookkeeping.
    best: (Ballot, Val),
    prepare_oks: usize,
    // Accept bookkeeping.
    accept_oks: usize,
    outcome: Option<(Val, bool)>,
    // Shared bookkeeping.
    replies: usize,
    max_conflict: Ballot,
    conflicts: usize,
    stale_age: Option<u64>,
}

impl RoundCore {
    /// Starts a full two-phase round. Returns the core and the prepare
    /// fan-out to send.
    pub fn new(
        key: Key,
        change: ChangeFn,
        ballot: Ballot,
        from: ProposerId,
        cfg: ClusterConfig,
        piggyback: bool,
    ) -> (Self, Vec<(u64, Request)>) {
        let msgs = cfg
            .acceptors
            .iter()
            .map(|&to| {
                (to, Request::Prepare { key: key.clone(), ballot, from })
            })
            .collect();
        let core = RoundCore {
            key,
            change,
            ballot,
            from,
            cfg,
            piggyback,
            phase: Phase::Prepare,
            token: 0,
            best: (Ballot::ZERO, Val::Empty),
            prepare_oks: 0,
            accept_oks: 0,
            outcome: None,
            replies: 0,
            max_conflict: Ballot::ZERO,
            conflicts: 0,
            stale_age: None,
        };
        (core, msgs)
    }

    /// Starts a one-round-trip round (§2.2.1): the proposer holds a
    /// quorum-confirmed promise for `ballot` and the cached current state
    /// `cached`, so the prepare phase is skipped entirely.
    pub fn new_cached(
        key: Key,
        change: ChangeFn,
        ballot: Ballot,
        cached: Val,
        from: ProposerId,
        cfg: ClusterConfig,
        piggyback: bool,
    ) -> (Self, Vec<(u64, Request)>) {
        let mut core = RoundCore {
            key,
            change,
            ballot,
            from,
            cfg,
            piggyback,
            phase: Phase::Accept,
            token: 0,
            best: (Ballot::ZERO, Val::Empty),
            prepare_oks: 0,
            accept_oks: 0,
            outcome: None,
            replies: 0,
            max_conflict: Ballot::ZERO,
            conflicts: 0,
            stale_age: None,
        };
        let msgs = core.start_accept(cached);
        (core, msgs)
    }

    /// The ballot this round runs at.
    pub fn ballot(&self) -> Ballot {
        self.ballot
    }

    /// Phase token to attach to in-flight requests: replies must echo it
    /// back into [`RoundCore::on_reply`], where stale tokens are dropped.
    pub fn token(&self) -> u32 {
        self.token
    }

    /// Highest conflicting ballot seen (for fast-forward on retry).
    pub fn max_conflict(&self) -> Ballot {
        self.max_conflict
    }

    fn quorum_impossible(&self, oks: usize, quorum: usize) -> bool {
        let remaining = self.cfg.acceptors.len() - self.replies;
        oks + remaining < quorum
    }

    fn start_accept(&mut self, cur: Val) -> Vec<(u64, Request)> {
        let applied = self.change.apply(&cur);
        self.outcome = Some((applied.next.clone(), applied.accepted));
        self.phase = Phase::Accept;
        self.token += 1;
        self.replies = 0;
        let promise_next =
            if self.piggyback { Some(self.ballot.next_for(self.from.id)) } else { None };
        self.cfg
            .acceptors
            .iter()
            .map(|&to| {
                (
                    to,
                    Request::Accept {
                        key: self.key.clone(),
                        ballot: self.ballot,
                        val: applied.next.clone(),
                        from: self.from,
                        promise_next,
                    },
                )
            })
            .collect()
    }

    fn finish(&mut self, result: CasResult<RoundOutcome>) -> Step {
        self.phase = Phase::Finished;
        Step::Done(result)
    }

    /// The error the round would fail with given the replies seen so
    /// far. Drivers report this on timeout so the same precedence as an
    /// in-round failure applies — age fence, then conflict (carrying
    /// the fast-forward ballot), then the per-phase quorum shortfall
    /// with the REAL ok-count: `got` distinguishes a dead cluster
    /// (`got: 0`) from a slow straggler (`got: quorum - 1`).
    pub fn timeout_error(&self) -> CasError {
        if let Some(required) = self.stale_age {
            CasError::StaleAge { required, got: self.from.age }
        } else if self.conflicts > 0 {
            CasError::Conflict(self.max_conflict)
        } else {
            let (needed, got) = match self.phase {
                Phase::Prepare => (self.cfg.quorum.prepare, self.prepare_oks),
                _ => (self.cfg.quorum.accept, self.accept_oks),
            };
            CasError::NoQuorum { needed, got }
        }
    }

    fn fail(&mut self) -> Step {
        let res = Err(self.timeout_error());
        self.finish(res)
    }

    /// Feeds one acceptor reply (or a transport failure as `None`).
    /// `token` must be the value of [`RoundCore::token`] at the time the
    /// corresponding request was sent; stale-phase replies are dropped.
    pub fn on_reply(&mut self, token: u32, _from: u64, resp: Option<Response>) -> Step {
        if self.phase == Phase::Finished || token != self.token {
            return Step::Continue; // late/stale reply: ignore
        }
        self.replies += 1;
        match resp {
            Some(Response::Conflict { seen }) => {
                self.conflicts += 1;
                self.max_conflict = self.max_conflict.max(seen);
            }
            Some(Response::StaleAge { required }) => {
                self.stale_age = Some(self.stale_age.unwrap_or(0).max(required));
            }
            Some(Response::Promise { accepted_ballot, accepted_val })
                if self.phase == Phase::Prepare =>
            {
                self.prepare_oks += 1;
                // "picks the value of the tuple with the highest ballot".
                if accepted_ballot >= self.best.0 {
                    self.best = (accepted_ballot, accepted_val);
                }
            }
            Some(Response::Accepted) if self.phase == Phase::Accept => {
                self.accept_oks += 1;
            }
            // Transport failure, Error response, or a phase-mismatched
            // reply (e.g. a promise arriving after we moved to accept —
            // impossible per driver contract, but harmless): counts only
            // toward `replies`.
            _ => {}
        }

        match self.phase {
            Phase::Prepare => {
                if self.prepare_oks >= self.cfg.quorum.prepare {
                    let cur = self.best.1.clone();
                    return Step::Send(self.start_accept(cur));
                }
                if self.stale_age.is_some()
                    || self.quorum_impossible(self.prepare_oks, self.cfg.quorum.prepare)
                {
                    return self.fail();
                }
                Step::Continue
            }
            Phase::Accept => {
                if self.accept_oks >= self.cfg.quorum.accept {
                    let (state, accepted) = self.outcome.clone().expect("accept implies outcome");
                    let next_promised =
                        if self.piggyback { Some(self.ballot.next_for(self.from.id)) } else { None };
                    let ballot = self.ballot;
                    return self.finish(Ok(RoundOutcome { state, accepted, ballot, next_promised }));
                }
                if self.stale_age.is_some()
                    || self.quorum_impossible(self.accept_oks, self.cfg.quorum.accept)
                {
                    return self.fail();
                }
                Step::Continue
            }
            Phase::Finished => Step::Continue,
        }
    }
}

/// What a quorum-read round wants the driver to do next.
#[derive(Debug)]
pub enum ReadStep {
    /// Waiting for more replies.
    Continue,
    /// Fast path decided: `Ok(value)` serves the read after ONE round
    /// trip and ZERO acceptor writes; `Err` is a hard protocol failure
    /// (the GC age fence).
    Done(CasResult<Val>),
    /// The fast path cannot be taken (disagreeing replies, a foreign
    /// promise in flight, or too many failures): the driver must run
    /// the classic identity-CAS round instead. Linearizability is never
    /// weakened — the fallback IS the §2.2 read.
    Fallback,
}

/// How a set of `(accepted_ballot, value, promise)` slot snapshots
/// reads out under the quorum-agreement rule (shared by [`ReadCore`]
/// and [`LeaseRound`] so the two fast paths can never diverge).
enum Agreement {
    /// A promise from another proposer sits above the max accepted
    /// ballot: a foreign write may be in flight.
    Blocked,
    /// `needed` replies agree on the max accepted ballot: this IS the
    /// committed value.
    Agreed(Val),
    /// Not decided yet (more replies could still tip it).
    Pending,
}

/// The agreement rule: serve the max-accepted-ballot value iff `needed`
/// snapshots report it and no promise above it belongs to a proposer
/// other than `self_id`.
fn agreement(states: &[(Ballot, Val, Ballot)], needed: usize, self_id: u64) -> Agreement {
    let Some(max_b) = states.iter().map(|(b, _, _)| *b).max() else {
        return Agreement::Pending;
    };
    if states.iter().any(|(_, _, p)| *p > max_b && p.proposer != self_id) {
        return Agreement::Blocked;
    }
    let matches = states.iter().filter(|(b, _, _)| *b == max_b).count();
    if matches < needed {
        return Agreement::Pending;
    }
    // A ballot is accepted with exactly one value, so every matching
    // reply carries the same one.
    match states.iter().find(|(b, _, _)| *b == max_b) {
        Some((_, v, _)) => Agreement::Agreed(v.clone()),
        None => Agreement::Pending,
    }
}

/// Sans-IO quorum-read state machine: one `Read` fan-out, no prepare, no
/// accept, no disk writes on any acceptor.
///
/// The fast path serves value `v` iff `max(prepare, accept)` replies
/// report the identical `(accepted_ballot, value)` pair, that ballot is
/// the highest accepted ballot seen, and no reply carries a *foreign*
/// promise above it. Safety sketch:
///
/// * a set that large intersects every accept quorum, so `v` is chosen
///   and no higher ballot can be chosen without telling one of our
///   replies — the read observes every write that completed before it
///   started;
/// * two quorum reads can never disagree: the second one's reply set
///   intersects whatever accept quorum chose the newer value;
/// * a higher *own* promise (this proposer's piggybacked §2.2.1 ballot)
///   does not block: any in-flight own write either already reached an
///   accept quorum (then it IS the max accepted ballot we match on) or
///   has not completed anywhere and the read linearizes before it.
///
/// A foreign promise above the accepted ballot means another proposer
/// may be mid-write — the conservative answer is the classic round.
pub struct ReadCore {
    from: ProposerId,
    cfg: ClusterConfig,
    replies: usize,
    /// (accepted_ballot, value, promise) per `ReadState` reply.
    states: Vec<(Ballot, Val, Ballot)>,
    finished: bool,
}

impl ReadCore {
    /// Starts a quorum read. Returns the core and the `Read` fan-out.
    pub fn new(key: Key, from: ProposerId, cfg: ClusterConfig) -> (Self, Vec<(u64, Request)>) {
        let msgs = cfg
            .acceptors
            .iter()
            .map(|&to| (to, Request::Read { key: key.clone(), from }))
            .collect();
        (ReadCore { from, cfg, replies: 0, states: Vec::new(), finished: false }, msgs)
    }

    /// Matching replies required to serve the fast path: a set this
    /// large intersects every prepare AND every accept quorum.
    pub fn needed(&self) -> usize {
        self.cfg.quorum.prepare.max(self.cfg.quorum.accept)
    }

    /// Feeds one acceptor reply (or a transport failure as `None`).
    pub fn on_reply(&mut self, _from: u64, resp: Option<Response>) -> ReadStep {
        if self.finished {
            return ReadStep::Continue; // late reply: ignore
        }
        self.replies += 1;
        match resp {
            Some(Response::ReadState { promise, accepted_ballot, accepted_val }) => {
                self.states.push((accepted_ballot, accepted_val, promise));
            }
            Some(Response::StaleAge { required }) => {
                // The GC fenced this proposer; a fallback round would be
                // fenced too, so fail hard like the classic path does.
                self.finished = true;
                return ReadStep::Done(Err(CasError::StaleAge {
                    required,
                    got: self.from.age,
                }));
            }
            // Transport failure or an unexpected response: counts only
            // toward `replies` (and therefore toward exhaustion).
            _ => {}
        }
        self.decide()
    }

    fn decide(&mut self) -> ReadStep {
        match agreement(&self.states, self.needed(), self.from.id) {
            Agreement::Blocked => {
                // A foreign write may be in flight; no later reply can
                // retract a promise, so fall back immediately.
                self.finished = true;
                return ReadStep::Fallback;
            }
            Agreement::Agreed(val) => {
                self.finished = true;
                return ReadStep::Done(Ok(val));
            }
            Agreement::Pending => {}
        }
        if self.replies >= self.cfg.acceptors.len() {
            // Everyone answered and no stable quorum emerged.
            self.finished = true;
            return ReadStep::Fallback;
        }
        ReadStep::Continue
    }
}

/// Outcome of one lease acquire/renew fan-out.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LeaseOutcome {
    /// Every configured acceptor granted: the 0-RTT window may be armed.
    pub complete: bool,
    /// How many acceptors granted (an incomplete round with grants > 0
    /// holds partial state worth revoking; an all-denied round holds
    /// nothing).
    pub grants: usize,
    /// The committed value per the read-quorum agreement rule over the
    /// grant snapshots (`None` when replies disagree or a foreign write
    /// is in flight) — lets an acquisition round double as a 1-RTT read.
    pub value: Option<Val>,
    /// When the round was sent (holder clock, µs). [`LeaseCore::install`]
    /// refuses to arm `value` while an unknown-outcome own write's
    /// straggler horizon covers this instant.
    pub t_send: u64,
    /// The key's own-write sequence number when the round was sent
    /// (`u64::MAX` if a write was mid-flight): [`LeaseCore::install`]
    /// arms `value` only if it is unchanged, i.e. no own write raced
    /// the round's snapshots.
    pub write_mark: u64,
    /// End of the holder's conservative serving window, on the
    /// *holder's* clock: `t_send + duration - skew_bound`.
    pub valid_until: u64,
    /// On a denied round, the proposer a denying acceptor named as the
    /// current leaseholder — the redirect target for a router that
    /// would rather hand the read to the 0-RTT holder than wait out
    /// the skew-bounded window. `None` when granted or unreported.
    pub holder: Option<u64>,
}

/// What a lease acquire/renew round wants the driver to do next.
#[derive(Debug)]
pub enum LeaseStep {
    /// Waiting for more replies.
    Continue,
    /// Every acceptor answered (or a grant became impossible).
    Done(LeaseOutcome),
}

/// Sans-IO lease acquire/renew round: one `LeaseAcquire`/`LeaseRenew`
/// fan-out whose replies snapshot each acceptor's slot.
///
/// The 0-RTT window arms only when **every** configured acceptor
/// grants. A mere quorum of grants is NOT enough under clock skew: a
/// foreign write needs one expired acceptor per quorum, and with
/// quorum-sized grant sets the single acceptor in the intersection of
/// the holder's and the writer's quorums can be the one whose clock
/// runs fast — its early expiry alone would break linearizability.
/// With a full grant set every foreign write quorum must contain at
/// least `nodes - skewed` honestly-measured leases, so up to
/// `fault_tolerance()` clocks may violate the skew bound without any
/// safety loss (the chaos suite drives exactly that). The price is
/// availability of the *fast path only*: any unreachable acceptor
/// degrades reads to the 1-RTT quorum path, never breaks them.
pub struct LeaseRound {
    holder: u64,
    n: usize,
    needed_match: usize,
    t_send: u64,
    write_mark: u64,
    valid_until: u64,
    replies: usize,
    grants: usize,
    denied: bool,
    /// Leaseholder named by a denying acceptor (`holder` above is the
    /// proposer RUNNING this round; this is who beat it to the lease).
    reported_holder: Option<u64>,
    /// (accepted_ballot, value, promise) per grant snapshot.
    states: Vec<(Ballot, Val, Ballot)>,
    finished: bool,
}

impl LeaseRound {
    fn new(
        holder: u64,
        cfg: &ClusterConfig,
        t_send: u64,
        write_mark: u64,
        valid_until: u64,
    ) -> Self {
        LeaseRound {
            holder,
            n: cfg.acceptors.len(),
            needed_match: cfg.quorum.prepare.max(cfg.quorum.accept),
            t_send,
            write_mark,
            valid_until,
            replies: 0,
            grants: 0,
            denied: false,
            reported_holder: None,
            states: Vec::new(),
            finished: false,
        }
    }

    /// Feeds one acceptor reply (or a transport failure as `None`).
    pub fn on_reply(&mut self, _from: u64, resp: Option<Response>) -> LeaseStep {
        if self.finished {
            return LeaseStep::Continue; // late reply: ignore
        }
        self.replies += 1;
        match resp {
            Some(Response::LeaseGranted { granted, promise, accepted_ballot, accepted_val, holder }) => {
                if granted {
                    self.grants += 1;
                } else {
                    self.denied = true;
                    if let Some(h) = holder {
                        self.reported_holder = Some(h);
                    }
                }
                self.states.push((accepted_ballot, accepted_val, promise));
            }
            // StaleAge, Error, unexpected response or transport failure:
            // this acceptor will not grant, so the set can't complete.
            _ => self.denied = true,
        }
        if self.replies >= self.n {
            self.finished = true;
            return LeaseStep::Done(self.outcome());
        }
        LeaseStep::Continue
    }

    /// The outcome from the replies seen so far (drivers call this on
    /// timeout; `on_reply` calls it once every acceptor answered).
    pub fn outcome(&self) -> LeaseOutcome {
        LeaseOutcome {
            complete: !self.denied && self.grants == self.n,
            grants: self.grants,
            value: self.decide_value(),
            t_send: self.t_send,
            write_mark: self.write_mark,
            valid_until: self.valid_until,
            holder: self.reported_holder,
        }
    }

    /// The shared [`agreement`] rule over the grant snapshots: serve
    /// the max-accepted-ballot value iff a read quorum reports it and
    /// no *foreign* promise sits above it.
    fn decide_value(&self) -> Option<Val> {
        match agreement(&self.states, self.needed_match, self.holder) {
            Agreement::Agreed(v) => Some(v),
            Agreement::Blocked | Agreement::Pending => None,
        }
    }
}

/// Result of a 0-RTT local-read attempt against [`LeaseCore`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LeaseRead {
    /// Lease live and value known: serve with zero transport sends.
    Hit(Val),
    /// Lease live but inside the renewal margin: the read should pay a
    /// renew round now (1 RTT) so later reads stay 0-RTT. The held
    /// value is deliberately NOT carried: the renew round may outlive
    /// the old window, so serving it after a failed renewal would be
    /// unsound — failures drop to the classic fallback instead.
    NeedsRenew,
    /// A previously held window has ended (a lease *break*).
    Expired,
    /// No lease held (or the window is armed but the value unknown).
    Miss,
}

/// Sans-IO per-proposer lease table: the grant/renew/expiry state
/// machine behind `ReadMode::Lease`.
///
/// All instants are µs on an injectable monotonic clock supplied by the
/// driver (`Instant`-derived in the real proposer, virtual time in the
/// simulator — which is how the chaos suite drives clock skew
/// deterministically). The serving window for a grant issued at
/// `t_send` is `[t_send, t_send + duration - skew_bound)`: it starts
/// counting *before* any acceptor could have started its own
/// `duration`-long window, so the holder always stops serving first as
/// long as relative clock-rate drift over one window stays under
/// `skew_bound`.
///
/// ## Safety argument (why a broken lease can only lose the fast path)
///
/// A local read is served only while (a) the window above is open and
/// (b) the latest acquire/renew round was granted by **every**
/// acceptor. For a foreign write to commit behind the holder's back it
/// needs an accept quorum of acceptors whose lease windows have ended
/// on their own clocks. Every such acceptor either measured honestly —
/// then its window outlives the holder's conservative one and the
/// write linearizes after local serving stopped — or violates the skew
/// bound. Since a full grant set leaves no quorum made only of
/// skew-violating acceptors (up to `fault_tolerance()` of them), every
/// break path — crash, restart (grants are WAL-durable and re-honored
/// after replay), partition of the holder, timeout, explicit revoke —
/// merely closes the 0-RTT window and drops the reader onto the 1-RTT
/// quorum path or the identity-CAS round, both linearizable on their
/// own.
pub struct LeaseCore {
    holder: u64,
    duration_us: u64,
    skew_us: u64,
    margin_us: u64,
    entries: std::collections::HashMap<Key, LeaseEntry>,
    /// Own-write tracking per key (see [`LeaseCore::write_started`]):
    /// grant-round values must not be armed over a concurrent own
    /// write whose commit the snapshots may have missed.
    writes: std::collections::HashMap<Key, WriteTrack>,
}

#[derive(Debug)]
struct LeaseEntry {
    /// Committed value as of the last agreement/own write; `None` while
    /// unknown (window may still be armed — blocks rivals, serves
    /// nothing).
    value: Option<Val>,
    /// End of the conservative serving window (holder clock, µs).
    valid_until: u64,
}

#[derive(Debug, Default)]
struct WriteTrack {
    /// Own writes currently in flight on the key.
    open: u32,
    /// Bumped on every completed own write: a grant round whose
    /// captured mark no longer matches raced a write (clock-resolution
    /// free, unlike a timestamp comparison).
    seq: u64,
    /// Instant (holder clock) before which grant-round snapshots may
    /// have missed an own write: known outcomes dirty up to their
    /// completion, unknown outcomes one extra lease duration (straggler
    /// accepts may land that long after).
    dirty_until: u64,
}

impl LeaseCore {
    /// New table for proposer `holder`. `duration_us` is what acquire
    /// rounds request; `skew_us` is subtracted from every serving
    /// window; reads within `margin_us` of expiry trigger a renewal
    /// round (the renew cadence).
    ///
    /// Inputs are made safe rather than rejected (a `Proposer` builds
    /// this even when leases are disabled): the duration is clamped to
    /// the acceptor-side grant cap — the holder's window math MUST
    /// match what an acceptor will actually honor, or windows past the
    /// cap would outlive every grant — and the skew bound is clamped
    /// below the duration so the serving window is never empty-by-
    /// underflow.
    pub fn new(holder: u64, duration_us: u64, skew_us: u64, margin_us: u64) -> Self {
        let duration_us = duration_us.clamp(1, crate::acceptor::MAX_LEASE_US);
        let skew_us = skew_us.min(duration_us - 1);
        LeaseCore {
            holder,
            duration_us,
            skew_us,
            margin_us,
            entries: std::collections::HashMap::new(),
            writes: std::collections::HashMap::new(),
        }
    }

    /// Marks one of the holder's own writes on `key` as in flight. A
    /// write committing between a grant round's acceptor snapshots and
    /// its install would otherwise arm the PRE-write value for 0-RTT
    /// serving (the snapshots can't see a commit that lands after
    /// them). Drivers call this when a write round starts and pair it
    /// with [`LeaseCore::write_finished`] on every exit path.
    pub fn write_started(&mut self, key: &Key) {
        self.writes.entry(key.clone()).or_default().open += 1;
    }

    /// Closes an own write at holder-clock `now_us`. `known` = the
    /// outcome is decided (committed, and noted via
    /// [`LeaseCore::note_write`]); unknown outcomes (timeouts,
    /// conflicts with possible minority accepts) keep value installs
    /// blocked for one extra lease duration — the horizon after which
    /// straggler accepts are presumed dead.
    pub fn write_finished(&mut self, key: &Key, now_us: u64, known: bool) {
        let horizon =
            if known { now_us } else { now_us.saturating_add(self.duration_us) };
        let track = self.writes.entry(key.clone()).or_default();
        track.open = track.open.saturating_sub(1);
        track.seq += 1;
        track.dirty_until = track.dirty_until.max(horizon);
        // Keep the map proportional to the active write set. The wide
        // retention margin keeps any round that could still hold a
        // matching mark from seeing its track vanish (absence reads as
        // mark 0, which the stale mark then fails to match anyway —
        // pruning can only over-block, never over-arm).
        if self.writes.len() > 4096 {
            let margin = 2 * self.duration_us;
            self.writes
                .retain(|_, w| w.open > 0 || w.dirty_until.saturating_add(margin) >= now_us);
        }
    }

    /// The key's current write mark, captured by [`LeaseCore::begin`]:
    /// the sequence number, or `u64::MAX` while a write is mid-flight
    /// (which no later state ever matches).
    fn write_mark(&self, key: &Key) -> u64 {
        match self.writes.get(key) {
            None => 0,
            Some(w) if w.open > 0 => u64::MAX,
            Some(w) => w.seq,
        }
    }

    /// True iff no own write raced a round begun with `outcome`'s mark:
    /// nothing in flight now, the sequence number is unchanged, and any
    /// unknown-outcome straggler horizon had passed by send time.
    fn writes_clean(&self, key: &Key, outcome: &LeaseOutcome) -> bool {
        match self.writes.get(key) {
            None => outcome.write_mark == 0,
            Some(w) => {
                w.open == 0 && w.seq == outcome.write_mark && w.dirty_until <= outcome.t_send
            }
        }
    }

    /// The requested lease duration (µs).
    pub fn duration_us(&self) -> u64 {
        self.duration_us
    }

    /// Attempts a 0-RTT local read at holder-clock `now_us`.
    pub fn local_read(&mut self, key: &Key, now_us: u64) -> LeaseRead {
        let expired = match self.entries.get(key) {
            None => return LeaseRead::Miss,
            Some(entry) => now_us >= entry.valid_until,
        };
        if expired {
            self.entries.remove(key);
            return LeaseRead::Expired;
        }
        let entry = &self.entries[key];
        match &entry.value {
            None => LeaseRead::Miss,
            Some(_) if now_us.saturating_add(self.margin_us) >= entry.valid_until => {
                LeaseRead::NeedsRenew
            }
            Some(v) => LeaseRead::Hit(v.clone()),
        }
    }

    /// Starts an acquire (no entry) or renew (entry held) round at
    /// holder-clock `now_us`. Returns the round and the full fan-out.
    pub fn begin(
        &self,
        key: &Key,
        now_us: u64,
        from: ProposerId,
        cfg: &ClusterConfig,
    ) -> (LeaseRound, Vec<(u64, Request)>) {
        let renew = self.entries.contains_key(key);
        let msgs = cfg
            .acceptors
            .iter()
            .map(|&to| {
                let req = if renew {
                    Request::LeaseRenew {
                        key: key.clone(),
                        duration_us: self.duration_us,
                        from,
                    }
                } else {
                    Request::LeaseAcquire {
                        key: key.clone(),
                        duration_us: self.duration_us,
                        from,
                    }
                };
                (to, req)
            })
            .collect();
        let valid_until = now_us.saturating_add(self.duration_us - self.skew_us);
        let mark = self.write_mark(key);
        (LeaseRound::new(self.holder, cfg, now_us, mark, valid_until), msgs)
    }

    /// Installs a finished round's outcome: a complete grant set arms
    /// (or re-arms) the window; anything else drops the entry. The
    /// round's VALUE is armed only when no own write raced the round
    /// ([`LeaseCore::write_started`]) — a valueless window still fences
    /// rivals, and the next read's renew round re-reads fresh
    /// snapshots. Returns whether the key is now lease-covered.
    pub fn install(&mut self, key: &Key, outcome: &LeaseOutcome) -> bool {
        if outcome.complete {
            let value = if self.writes_clean(key, outcome) {
                outcome.value.clone()
            } else {
                None
            };
            self.entries
                .insert(key.clone(), LeaseEntry { value, valid_until: outcome.valid_until });
            true
        } else {
            self.entries.remove(key);
            false
        }
    }

    /// Records this proposer's own committed write. While the window is
    /// open only the holder can commit (acceptors reject foreign
    /// ballots), so the written state IS the register's current value.
    pub fn note_write(&mut self, key: &Key, val: Val, now_us: u64) {
        let live = match self.entries.get(key) {
            None => return,
            Some(entry) => now_us < entry.valid_until,
        };
        if live {
            if let Some(entry) = self.entries.get_mut(key) {
                entry.value = Some(val);
            }
        } else {
            self.entries.remove(key);
        }
    }

    /// Drops a key's lease state (own-write conflict, GC sync). Returns
    /// true if a lease was actually held (a break worth counting).
    pub fn invalidate(&mut self, key: &Key) -> bool {
        self.entries.remove(key).is_some()
    }

    /// Keys currently holding (possibly expired) lease state — the set
    /// to revoke on a configuration change.
    pub fn held_keys(&self) -> Vec<Key> {
        self.entries.keys().cloned().collect()
    }

    /// Keys whose serving window ends within `horizon_us` of `now_us`
    /// (windows that already ended included): the set a background
    /// renewal timer refreshes each tick so hot keys stay 0-RTT-covered
    /// across read gaps instead of breaking on the first read after a
    /// lull. Callers pass their tick interval (plus slack) as the
    /// horizon so every window is renewed before it can lapse.
    pub fn keys_expiring_within(&self, now_us: u64, horizon_us: u64) -> Vec<Key> {
        let cutoff = now_us.saturating_add(horizon_us);
        self.entries
            .iter()
            .filter(|(_, e)| e.valid_until <= cutoff)
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Drops everything (configuration change).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Number of keys with lease state.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if no lease state is held.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg3() -> ClusterConfig {
        ClusterConfig::majority(1, vec![1, 2, 3])
    }

    fn promise_empty() -> Response {
        Response::Promise { accepted_ballot: Ballot::ZERO, accepted_val: Val::Empty }
    }

    #[test]
    fn happy_two_phase_round() {
        let (mut core, msgs) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(7),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        assert_eq!(msgs.len(), 3);
        assert!(matches!(msgs[0].1, Request::Prepare { .. }));

        assert!(matches!(core.on_reply(core.token(), 1, Some(promise_empty())), Step::Continue));
        let accepts = match core.on_reply(core.token(), 2, Some(promise_empty())) {
            Step::Send(m) => m,
            s => panic!("expected accept fan-out, got {s:?}"),
        };
        assert_eq!(accepts.len(), 3);
        assert!(matches!(core.on_reply(core.token(), 1, Some(Response::Accepted)), Step::Continue));
        match core.on_reply(core.token(), 2, Some(Response::Accepted)) {
            Step::Done(Ok(out)) => {
                assert_eq!(out.state.as_num(), Some(7));
                assert!(out.accepted);
                assert_eq!(out.next_promised, None);
            }
            s => panic!("{s:?}"),
        }
        // Late reply ignored.
        assert!(matches!(core.on_reply(core.token(), 3, Some(Response::Accepted)), Step::Continue));
    }

    #[test]
    fn picks_highest_ballot_value() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Add(1),
            Ballot::new(5, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 
            1,
            Some(Response::Promise {
                accepted_ballot: Ballot::new(2, 2),
                accepted_val: Val::Num { ver: 0, num: 10 },
            }),
        );
        let step = core.on_reply(core.token(), 
            2,
            Some(Response::Promise {
                accepted_ballot: Ballot::new(3, 3),
                accepted_val: Val::Num { ver: 1, num: 20 },
            }),
        );
        match step {
            Step::Send(msgs) => match &msgs[0].1 {
                Request::Accept { val, .. } => {
                    assert_eq!(val.as_num(), Some(21), "Add(1) applied to the ballot-3 value")
                }
                r => panic!("{r:?}"),
            },
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn conflict_fails_round_with_max_ballot() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 1, Some(Response::Conflict { seen: Ballot::new(9, 2) }));
        // After two conflicts only one reply remains: quorum of 2 is
        // impossible, so the round fails fast carrying the max ballot.
        match core.on_reply(core.token(), 2, Some(Response::Conflict { seen: Ballot::new(4, 3) })) {
            Step::Done(Err(CasError::Conflict(b))) => assert_eq!(b, Ballot::new(9, 2)),
            s => panic!("{s:?}"),
        }
        // Late reply is ignored.
        assert!(matches!(core.on_reply(core.token(), 3, Some(promise_empty())), Step::Continue));
    }

    #[test]
    fn transport_failures_fail_quorum() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 1, None);
        match core.on_reply(core.token(), 2, None) {
            Step::Done(Err(CasError::NoQuorum { needed: 2, got: 0 })) => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn timeout_error_reports_real_reply_counts() {
        // One promise arrived, then the round stalls: the timeout error
        // must say got=1, not got=0 — a slow straggler is not a dead
        // cluster.
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        assert!(matches!(
            core.timeout_error(),
            CasError::NoQuorum { needed: 2, got: 0 }
        ));
        core.on_reply(core.token(), 1, Some(promise_empty()));
        assert!(matches!(
            core.timeout_error(),
            CasError::NoQuorum { needed: 2, got: 1 }
        ));
        // In the accept phase the count tracks accept oks.
        core.on_reply(core.token(), 2, Some(promise_empty()));
        core.on_reply(core.token(), 1, Some(Response::Accepted));
        assert!(matches!(
            core.timeout_error(),
            CasError::NoQuorum { needed: 2, got: 1 }
        ));
        // A conflict seen before the stall still wins the precedence.
        core.on_reply(core.token(), 2, Some(Response::Conflict { seen: Ballot::new(9, 2) }));
        assert!(matches!(core.timeout_error(), CasError::Conflict(b) if b == Ballot::new(9, 2)));
    }

    #[test]
    fn survives_one_failure_of_three() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 1, None);
        core.on_reply(core.token(), 2, Some(promise_empty()));
        let step = core.on_reply(core.token(), 3, Some(promise_empty()));
        assert!(matches!(step, Step::Send(_)), "quorum reached despite one failure");
    }

    #[test]
    fn cached_round_skips_prepare() {
        let (mut core, msgs) = RoundCore::new_cached(
            "k".into(),
            ChangeFn::Add(5),
            Ballot::new(2, 1),
            Val::Num { ver: 0, num: 10 },
            ProposerId::new(1),
            cfg3(),
            true,
        );
        assert!(matches!(msgs[0].1, Request::Accept { .. }), "no prepare phase");
        match &msgs[0].1 {
            Request::Accept { val, promise_next, .. } => {
                assert_eq!(val.as_num(), Some(15));
                assert_eq!(*promise_next, Some(Ballot::new(3, 1)));
            }
            _ => unreachable!(),
        }
        core.on_reply(core.token(), 1, Some(Response::Accepted));
        match core.on_reply(core.token(), 2, Some(Response::Accepted)) {
            Step::Done(Ok(out)) => {
                assert_eq!(out.state.as_num(), Some(15));
                assert_eq!(out.next_promised, Some(Ballot::new(3, 1)));
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn rejected_cas_still_completes_with_current_state() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Cas { expect: 99, val: 1 },
            Ballot::new(5, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        core.on_reply(core.token(), 
            1,
            Some(Response::Promise {
                accepted_ballot: Ballot::new(1, 1),
                accepted_val: Val::Num { ver: 3, num: 42 },
            }),
        );
        let step = core.on_reply(core.token(), 2, Some(promise_empty()));
        let Step::Send(_) = step else { panic!("{step:?}") };
        core.on_reply(core.token(), 1, Some(Response::Accepted));
        match core.on_reply(core.token(), 2, Some(Response::Accepted)) {
            Step::Done(Ok(out)) => {
                assert!(!out.accepted, "stale CAS is rejected");
                assert_eq!(out.state.as_num(), Some(42), "current state returned");
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn stale_age_aborts() {
        let (mut core, _) = RoundCore::new(
            "k".into(),
            ChangeFn::Read,
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg3(),
            false,
        );
        // A single StaleAge aborts immediately: the GC fenced this
        // proposer and no quorum outcome can be trusted.
        match core.on_reply(core.token(), 1, Some(Response::StaleAge { required: 3 })) {
            Step::Done(Err(CasError::StaleAge { required: 3, got: 0 })) => {}
            s => panic!("{s:?}"),
        }
    }

    fn read_state(c: u64, p: u64, num: i64, promise: Ballot) -> Response {
        Response::ReadState {
            promise,
            accepted_ballot: Ballot::new(c, p),
            accepted_val: Val::Num { ver: 0, num },
        }
    }

    #[test]
    fn quorum_read_serves_matching_quorum_in_one_round() {
        let (mut core, msgs) =
            ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        assert_eq!(msgs.len(), 3);
        assert!(matches!(msgs[0].1, Request::Read { .. }));
        assert!(matches!(
            core.on_reply(1, Some(read_state(4, 1, 42, Ballot::ZERO))),
            ReadStep::Continue
        ));
        match core.on_reply(2, Some(read_state(4, 1, 42, Ballot::ZERO))) {
            ReadStep::Done(Ok(v)) => assert_eq!(v.as_num(), Some(42)),
            s => panic!("expected fast-path read, got {s:?}"),
        }
        // Late reply ignored.
        assert!(matches!(
            core.on_reply(3, Some(read_state(4, 1, 42, Ballot::ZERO))),
            ReadStep::Continue
        ));
    }

    #[test]
    fn quorum_read_of_absent_key_serves_empty() {
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        let empty = || Response::ReadState {
            promise: Ballot::ZERO,
            accepted_ballot: Ballot::ZERO,
            accepted_val: Val::Empty,
        };
        core.on_reply(1, Some(empty()));
        match core.on_reply(2, Some(empty())) {
            ReadStep::Done(Ok(v)) => assert!(v.is_empty()),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_falls_back_on_disagreeing_replies() {
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        core.on_reply(1, Some(read_state(4, 1, 42, Ballot::ZERO)));
        core.on_reply(2, Some(read_state(5, 2, 43, Ballot::ZERO)));
        // All three answered, max ballot has only one vote: fallback.
        match core.on_reply(3, Some(read_state(4, 1, 42, Ballot::ZERO))) {
            ReadStep::Fallback => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_falls_back_on_foreign_promise() {
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        // Acceptor 1 promised ballot (7, 2) to ANOTHER proposer: a write
        // may be in flight — immediate fallback.
        match core.on_reply(1, Some(read_state(4, 1, 42, Ballot::new(7, 2)))) {
            ReadStep::Fallback => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_ignores_own_piggybacked_promise() {
        // Proposer 9 reads a key it also writes: acceptors hold its own
        // §2.2.1 piggybacked promise. That must NOT force a fallback.
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        core.on_reply(1, Some(read_state(4, 9, 42, Ballot::new(5, 9))));
        match core.on_reply(2, Some(read_state(4, 9, 42, Ballot::new(5, 9)))) {
            ReadStep::Done(Ok(v)) => assert_eq!(v.as_num(), Some(42)),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_newer_accepted_wins_the_match() {
        // One acceptor is ahead: its ballot is the max, so the stale
        // pair can never satisfy the fast path.
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        core.on_reply(1, Some(read_state(9, 2, 99, Ballot::ZERO)));
        core.on_reply(2, Some(read_state(4, 1, 42, Ballot::ZERO)));
        match core.on_reply(3, Some(read_state(9, 2, 99, Ballot::ZERO))) {
            ReadStep::Done(Ok(v)) => {
                assert_eq!(v.as_num(), Some(99), "must serve the NEWER committed value")
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_transport_failures_force_fallback() {
        let (mut core, _) = ReadCore::new("k".into(), ProposerId::new(9), cfg3());
        core.on_reply(1, None);
        core.on_reply(2, Some(read_state(4, 1, 42, Ballot::ZERO)));
        match core.on_reply(3, None) {
            ReadStep::Fallback => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_stale_age_fails_hard() {
        let (mut core, _) =
            ReadCore::new("k".into(), ProposerId { id: 9, age: 1 }, cfg3());
        match core.on_reply(1, Some(Response::StaleAge { required: 3 })) {
            ReadStep::Done(Err(CasError::StaleAge { required: 3, got: 1 })) => {}
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn quorum_read_respects_flexible_quorums() {
        // 4 nodes, prepare=2, accept=3: the read quorum is max(2,3)=3.
        let cfg = ClusterConfig {
            epoch: 1,
            acceptors: vec![1, 2, 3, 4],
            quorum: crate::quorum::QuorumSpec::flexible(4, 2, 3).unwrap(),
        };
        let (mut core, msgs) = ReadCore::new("k".into(), ProposerId::new(9), cfg);
        assert_eq!(msgs.len(), 4);
        assert_eq!(core.needed(), 3);
        core.on_reply(1, Some(read_state(4, 1, 42, Ballot::ZERO)));
        assert!(matches!(
            core.on_reply(2, Some(read_state(4, 1, 42, Ballot::ZERO))),
            ReadStep::Continue
        ));
        assert!(matches!(
            core.on_reply(3, Some(read_state(4, 1, 42, Ballot::ZERO))),
            ReadStep::Done(Ok(_))
        ));
    }

    fn granted(c: u64, p: u64, num: i64, promise: Ballot) -> Response {
        Response::LeaseGranted {
            granted: true,
            promise,
            accepted_ballot: Ballot::new(c, p),
            accepted_val: Val::Num { ver: 0, num },
            holder: None,
        }
    }

    fn lease_core() -> LeaseCore {
        // duration 1s, skew bound 100ms, renew margin 200ms.
        LeaseCore::new(9, 1_000_000, 100_000, 200_000)
    }

    #[test]
    fn lease_round_arms_only_on_full_grant_set() {
        let core = lease_core();
        let (mut round, msgs) = core.begin(&"k".into(), 0, ProposerId::new(9), &cfg3());
        assert_eq!(msgs.len(), 3, "acquire fans out to EVERY acceptor");
        assert!(matches!(msgs[0].1, Request::LeaseAcquire { .. }));
        let ok = granted(4, 1, 42, Ballot::ZERO);
        assert!(matches!(round.on_reply(1, Some(ok.clone())), LeaseStep::Continue));
        assert!(matches!(round.on_reply(2, Some(ok.clone())), LeaseStep::Continue));
        match round.on_reply(3, Some(ok)) {
            LeaseStep::Done(out) => {
                assert!(out.complete);
                assert_eq!(out.value.as_ref().and_then(|v| v.as_num()), Some(42));
                assert_eq!(out.valid_until, 900_000, "duration minus skew bound");
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn lease_round_quorum_of_grants_is_not_enough() {
        // 2 grants + 1 transport failure: a quorum, but under clock
        // skew a quorum-sized grant set is unsafe — must not arm.
        let core = lease_core();
        let (mut round, _) = core.begin(&"k".into(), 0, ProposerId::new(9), &cfg3());
        round.on_reply(1, Some(granted(4, 1, 42, Ballot::ZERO)));
        round.on_reply(2, Some(granted(4, 1, 42, Ballot::ZERO)));
        match round.on_reply(3, None) {
            LeaseStep::Done(out) => {
                assert!(!out.complete, "a failed acceptor must block the 0-RTT window");
                // ...but the read itself is still decided 1-RTT.
                assert_eq!(out.value.as_ref().and_then(|v| v.as_num()), Some(42));
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn lease_round_denial_blocks_window_but_can_serve_value() {
        let core = lease_core();
        let (mut round, _) = core.begin(&"k".into(), 0, ProposerId::new(9), &cfg3());
        round.on_reply(1, Some(granted(4, 1, 42, Ballot::ZERO)));
        round.on_reply(2, Some(granted(4, 1, 42, Ballot::ZERO)));
        let denial = Response::LeaseGranted {
            granted: false,
            promise: Ballot::ZERO,
            accepted_ballot: Ballot::new(4, 1),
            accepted_val: Val::Num { ver: 0, num: 42 },
            holder: Some(2),
        };
        match round.on_reply(3, Some(denial)) {
            LeaseStep::Done(out) => {
                assert!(!out.complete, "a foreign leaseholder denies the window");
                assert_eq!(out.value.as_ref().and_then(|v| v.as_num()), Some(42));
                assert_eq!(out.holder, Some(2), "the denial names the redirect target");
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn lease_round_foreign_promise_blocks_value() {
        let core = lease_core();
        let (mut round, _) = core.begin(&"k".into(), 0, ProposerId::new(9), &cfg3());
        round.on_reply(1, Some(granted(4, 1, 42, Ballot::new(7, 2))));
        round.on_reply(2, Some(granted(4, 1, 42, Ballot::ZERO)));
        match round.on_reply(3, Some(granted(4, 1, 42, Ballot::ZERO))) {
            LeaseStep::Done(out) => {
                assert!(out.complete, "grants are complete");
                assert!(out.value.is_none(), "a foreign in-flight write blocks the value");
            }
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn lease_round_own_promise_does_not_block() {
        let core = lease_core();
        let (mut round, _) = core.begin(&"k".into(), 0, ProposerId::new(9), &cfg3());
        for a in 1..=3 {
            round.on_reply(a, Some(granted(4, 9, 42, Ballot::new(5, 9))));
        }
        assert_eq!(round.outcome().value.as_ref().and_then(|v| v.as_num()), Some(42));
    }

    #[test]
    fn lease_local_read_lifecycle() {
        let mut core = lease_core();
        let key: Key = "k".into();
        assert_eq!(core.local_read(&key, 0), LeaseRead::Miss);
        // Arm a window [0, 900_000) with value 7.
        let out = LeaseOutcome {
            complete: true,
            grants: 3,
            value: Some(Val::Num { ver: 0, num: 7 }),
            t_send: 0,
            write_mark: 0,
            valid_until: 900_000,
            holder: None,
        };
        assert!(core.install(&key, &out));
        match core.local_read(&key, 100_000) {
            LeaseRead::Hit(v) => assert_eq!(v.as_num(), Some(7)),
            r => panic!("{r:?}"),
        }
        // Inside the 200ms renewal margin: the read must renew.
        assert_eq!(core.local_read(&key, 750_000), LeaseRead::NeedsRenew);
        // Past the window: a break; the entry is gone.
        assert_eq!(core.local_read(&key, 900_000), LeaseRead::Expired);
        assert_eq!(core.local_read(&key, 900_000), LeaseRead::Miss);
    }

    #[test]
    fn lease_note_write_keeps_value_current() {
        let mut core = lease_core();
        let key: Key = "k".into();
        core.install(
            &key,
            &LeaseOutcome {
                complete: true,
                grants: 3,
                value: None,
                t_send: 0,
                write_mark: 0,
                valid_until: 900_000,
                holder: None,
            },
        );
        // Window armed, value unknown: Miss (rivals blocked, nothing
        // served) until our own write fills it.
        assert_eq!(core.local_read(&key, 1), LeaseRead::Miss);
        core.note_write(&key, Val::Num { ver: 0, num: 5 }, 10);
        match core.local_read(&key, 11) {
            LeaseRead::Hit(v) => assert_eq!(v.as_num(), Some(5)),
            r => panic!("{r:?}"),
        }
        // A write AFTER expiry must not resurrect the window.
        core.note_write(&key, Val::Num { ver: 1, num: 6 }, 2_000_000);
        assert_eq!(core.local_read(&key, 2_000_001), LeaseRead::Miss);
    }

    #[test]
    fn lease_install_failure_drops_entry_and_renew_uses_renew_message() {
        let mut core = lease_core();
        let key: Key = "k".into();
        core.install(
            &key,
            &LeaseOutcome {
                complete: true,
                grants: 3,
                value: Some(Val::Num { ver: 0, num: 1 }),
                t_send: 0,
                write_mark: 0,
                valid_until: 900_000,
                holder: None,
            },
        );
        // Held entry: the next round is a renew.
        let (_, msgs) = core.begin(&key, 500_000, ProposerId::new(9), &cfg3());
        assert!(matches!(msgs[0].1, Request::LeaseRenew { .. }));
        // Failed round: entry dropped, next round is an acquire again.
        assert!(!core.install(
            &key,
            &LeaseOutcome {
                complete: false,
                grants: 0,
                value: None,
                t_send: 0,
                write_mark: 0,
                valid_until: 0,
                holder: None,
            }
        ));
        assert!(core.is_empty());
        let (_, msgs) = core.begin(&key, 600_000, ProposerId::new(9), &cfg3());
        assert!(matches!(msgs[0].1, Request::LeaseAcquire { .. }));
    }

    /// Completes a begun round with `n` identical grants and returns
    /// its outcome (all-N grant set, agreed value `num`).
    fn grant_all(mut round: LeaseRound, num: i64) -> LeaseOutcome {
        let mut last = None;
        for a in 1..=3 {
            if let LeaseStep::Done(out) = round.on_reply(a, Some(granted(4, 1, num, Ballot::ZERO)))
            {
                last = Some(out);
            }
        }
        last.expect("3 replies complete the round")
    }

    #[test]
    fn racing_own_write_blocks_value_install() {
        // A write committing between a grant round's snapshots and its
        // install must not let the PRE-write value arm for 0-RTT
        // serving: the window arms, the value does not.
        let mut core = lease_core();
        let key: Key = "k".into();
        // Round begun at t=100 while a write is already in flight...
        core.write_started(&key);
        let (round, _) = core.begin(&key, 100, ProposerId::new(9), &cfg3());
        let raced = grant_all(round, 7);
        // ...and the write commits (same clock µs or later) mid-round.
        core.write_finished(&key, 100, true);
        assert!(core.install(&key, &raced), "window still arms (rivals stay fenced)");
        assert_eq!(core.local_read(&key, 300), LeaseRead::Miss, "stale value must not serve");
        // The write's own note_write (which carries the NEW value) and
        // a later round's fresh snapshots are the repair paths.
        core.note_write(&key, Val::Num { ver: 1, num: 8 }, 300);
        match core.local_read(&key, 301) {
            LeaseRead::Hit(v) => assert_eq!(v.as_num(), Some(8)),
            r => panic!("{r:?}"),
        }
        // A round begun AFTER the write completed is clean again — even
        // at the very same clock reading (the mark is logical).
        let (round, _) = core.begin(&key, 100, ProposerId::new(9), &cfg3());
        let clean = grant_all(round, 8);
        assert!(core.install(&key, &clean));
        assert!(matches!(core.local_read(&key, 500), LeaseRead::Hit(_)));
    }

    #[test]
    fn unknown_outcome_write_poisons_installs_for_horizon() {
        // A timed-out/conflicted write's accepts may land long after the
        // error: rounds begun within one lease duration of it must not
        // arm their value.
        let mut core = lease_core(); // duration 1s
        let key: Key = "k".into();
        core.write_started(&key);
        core.write_finished(&key, 1_000, false); // unknown: dirty to 1_001_000
        let (round, _) = core.begin(&key, 500_000, ProposerId::new(9), &cfg3());
        let inside = grant_all(round, 7);
        core.install(&key, &inside);
        assert_eq!(core.local_read(&key, 600_000), LeaseRead::Miss);
        // Past the straggler horizon the same flow arms again.
        let (round, _) = core.begin(&key, 1_100_000, ProposerId::new(9), &cfg3());
        let beyond = grant_all(round, 7);
        core.install(&key, &beyond);
        assert!(matches!(core.local_read(&key, 1_200_000), LeaseRead::Hit(_)));
    }

    #[test]
    fn lease_core_clamps_degenerate_opts() {
        // Requesting more than the acceptor-side cap must clamp the
        // HOLDER's window too, or it would outlive every grant.
        let core = LeaseCore::new(1, u64::MAX, 100, 0);
        assert_eq!(core.duration_us(), crate::acceptor::MAX_LEASE_US);
        // Zeroed opts must not panic (Proposer builds a LeaseCore even
        // when leases are disabled).
        let _ = LeaseCore::new(1, 0, 0, 0);
        // Skew at/above duration clamps below it (non-empty window).
        let core = LeaseCore::new(9, 1_000, 5_000, 0);
        let (round, _) = core.begin(&"k".into(), 0, ProposerId::new(9), &cfg3());
        assert!(round.outcome().valid_until >= 1, "window must be non-empty");
    }

    #[test]
    fn lease_invalidate_and_clear() {
        let mut core = lease_core();
        for k in ["a", "b"] {
            core.install(
                &k.to_string(),
                &LeaseOutcome {
                    complete: true,
                    grants: 3,
                    value: None,
                    t_send: 0,
                    write_mark: 0,
                    valid_until: 1_000,
                    holder: None,
                },
            );
        }
        assert_eq!(core.len(), 2);
        assert!(core.invalidate(&"a".to_string()));
        assert!(!core.invalidate(&"a".to_string()), "second invalidate is a no-op");
        let mut held = core.held_keys();
        held.sort();
        assert_eq!(held, vec!["b".to_string()]);
        core.clear();
        assert!(core.is_empty());
    }

    #[test]
    fn keys_expiring_within_scans_the_renewal_set() {
        let mut core = lease_core();
        let arm = |core: &mut LeaseCore, k: &str, until: u64| {
            core.install(
                &k.to_string(),
                &LeaseOutcome {
                    complete: true,
                    grants: 3,
                    value: Some(Val::Num { ver: 0, num: 1 }),
                    t_send: 0,
                    write_mark: 0,
                    valid_until: until,
                    holder: None,
                },
            );
        };
        arm(&mut core, "soon", 100_000);
        arm(&mut core, "later", 900_000);
        arm(&mut core, "lapsed", 10_000); // window already ended
        // At t=50ms with a 100ms horizon: "soon" (ends in 50ms) and
        // "lapsed" (already ended — renew to re-arm) are due; "later"
        // (850ms away) is not.
        let mut due = core.keys_expiring_within(50_000, 100_000);
        due.sort();
        assert_eq!(due, vec!["lapsed".to_string(), "soon".to_string()]);
        assert!(core.keys_expiring_within(0, 0).iter().all(|k| k == "lapsed"));
    }

    #[test]
    fn flexible_quorum_respected() {
        // paper §2.3: 4 nodes, prepare=2, accept=3
        let cfg = ClusterConfig {
            epoch: 1,
            acceptors: vec![1, 2, 3, 4],
            quorum: crate::quorum::QuorumSpec::flexible(4, 2, 3).unwrap(),
        };
        let (mut core, msgs) = RoundCore::new(
            "k".into(),
            ChangeFn::Set(1),
            Ballot::new(1, 1),
            ProposerId::new(1),
            cfg,
            false,
        );
        assert_eq!(msgs.len(), 4);
        core.on_reply(core.token(), 1, Some(promise_empty()));
        let Step::Send(_) = core.on_reply(core.token(), 2, Some(promise_empty())) else {
            panic!("prepare quorum of 2")
        };
        core.on_reply(core.token(), 1, Some(Response::Accepted));
        core.on_reply(core.token(), 2, Some(Response::Accepted));
        assert!(matches!(core.on_reply(core.token(), 3, Some(Response::Accepted)), Step::Done(Ok(_))));
    }
}
