//! Wire protocol between proposers and acceptors.
//!
//! Two request/response pairs — prepare/promise and accept/accepted —
//! plus the admin messages the deletion GC (§3.1) and membership change
//! (§2.3) need. Every proposer message carries the proposer's *age* so
//! acceptors can reject messages from proposers that were alive before a
//! deletion was garbage-collected (the lost-delete anomaly guard).
//!
//! Messages implement the in-tree [`Codec`] (the wire format of the TCP
//! transport and the record format of the acceptor log).

use crate::ballot::Ballot;
use crate::codec::{decode_seq, encode_seq, Codec, CodecError};
use crate::state::Val;

/// Register key. Keys name independent CASPaxos instances (§3).
pub type Key = String;

/// Proposer identity + age, attached to every request (§3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProposerId {
    /// Numeric proposer id (ballot tiebreaker).
    pub id: u64,
    /// Age, incremented by the GC when it invalidates proposer caches.
    pub age: u64,
}

impl ProposerId {
    /// A proposer at age 0.
    pub fn new(id: u64) -> Self {
        ProposerId { id, age: 0 }
    }
}

impl Codec for ProposerId {
    fn encode(&self, out: &mut Vec<u8>) {
        self.id.encode(out);
        self.age.encode(out);
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(ProposerId { id: u64::decode(input)?, age: u64::decode(input)? })
    }
}

/// Request sent from a proposer to an acceptor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Prepare phase: "promise me ballot `ballot` on `key`".
    Prepare {
        /// Target register.
        key: Key,
        /// Ballot the proposer wants promised.
        ballot: Ballot,
        /// Sender identity + age.
        from: ProposerId,
    },
    /// Accept phase: "accept (`ballot`, `val`) on `key`".
    Accept {
        /// Target register.
        key: Key,
        /// Ballot from the prepare phase (or piggybacked 1-RTT ballot).
        ballot: Ballot,
        /// The new state produced by the change function.
        val: Val,
        /// Sender identity + age.
        from: ProposerId,
        /// One-round-trip optimization (§2.2.1): also promise the *next*
        /// ballot so the proposer can skip the next prepare phase.
        promise_next: Option<Ballot>,
    },
    /// GC step 2c (§3.1): require messages from proposer `proposer_id` to
    /// carry age ≥ `min_age`.
    SetMinAge {
        /// Proposer whose old incarnations must be rejected.
        proposer_id: u64,
        /// Minimum acceptable age.
        min_age: u64,
    },
    /// GC step 2d (§3.1): remove the register if it still holds the
    /// tombstone accepted at `tombstone_ballot`.
    Erase {
        /// Target register.
        key: Key,
        /// The ballot the tombstone was accepted at in GC step 2a.
        tombstone_ballot: Ballot,
    },
    /// Membership catch-up (§2.3.3): dump acceptor state for replication
    /// onto a fresh node. `after` allows incremental sync.
    Dump {
        /// Only keys lexicographically greater than this (None = all).
        after: Option<Key>,
        /// Max entries to return.
        limit: usize,
    },
    /// Membership catch-up: install a dumped slot if it is newer than the
    /// local one (conflict resolved by ballot, §2.3.3).
    Install {
        /// Register key.
        key: Key,
        /// Accepted ballot of the dumped slot.
        ballot: Ballot,
        /// Accepted value of the dumped slot.
        val: Val,
    },
    /// Liveness probe (used by examples and the TCP server).
    Ping,
    /// Quorum-read fast path: report the register's slot *without
    /// mutating or persisting anything*. The proposer serves the read in
    /// one round trip iff a read quorum reports a matching stable state
    /// (see `proposer::core::ReadCore`); otherwise it falls back to the
    /// classic identity-CAS round, so linearizability is never weakened.
    Read {
        /// Target register.
        key: Key,
        /// Sender identity + age (the GC fence applies to reads too).
        from: ProposerId,
    },
}

impl Request {
    /// The register this request targets, if any.
    pub fn key(&self) -> Option<&Key> {
        match self {
            Request::Prepare { key, .. }
            | Request::Accept { key, .. }
            | Request::Erase { key, .. }
            | Request::Install { key, .. }
            | Request::Read { key, .. } => Some(key),
            _ => None,
        }
    }
}

impl Codec for Request {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Request::Prepare { key, ballot, from } => {
                out.push(0);
                key.encode(out);
                ballot.encode(out);
                from.encode(out);
            }
            Request::Accept { key, ballot, val, from, promise_next } => {
                out.push(1);
                key.encode(out);
                ballot.encode(out);
                val.encode(out);
                from.encode(out);
                promise_next.encode(out);
            }
            Request::SetMinAge { proposer_id, min_age } => {
                out.push(2);
                proposer_id.encode(out);
                min_age.encode(out);
            }
            Request::Erase { key, tombstone_ballot } => {
                out.push(3);
                key.encode(out);
                tombstone_ballot.encode(out);
            }
            Request::Dump { after, limit } => {
                out.push(4);
                after.encode(out);
                limit.encode(out);
            }
            Request::Install { key, ballot, val } => {
                out.push(5);
                key.encode(out);
                ballot.encode(out);
                val.encode(out);
            }
            Request::Ping => out.push(6),
            Request::Read { key, from } => {
                out.push(7);
                key.encode(out);
                from.encode(out);
            }
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(match u8::decode(input)? {
            0 => Request::Prepare {
                key: Key::decode(input)?,
                ballot: Ballot::decode(input)?,
                from: ProposerId::decode(input)?,
            },
            1 => Request::Accept {
                key: Key::decode(input)?,
                ballot: Ballot::decode(input)?,
                val: Val::decode(input)?,
                from: ProposerId::decode(input)?,
                promise_next: Option::<Ballot>::decode(input)?,
            },
            2 => Request::SetMinAge {
                proposer_id: u64::decode(input)?,
                min_age: u64::decode(input)?,
            },
            3 => Request::Erase {
                key: Key::decode(input)?,
                tombstone_ballot: Ballot::decode(input)?,
            },
            4 => Request::Dump { after: Option::<Key>::decode(input)?, limit: usize::decode(input)? },
            5 => Request::Install {
                key: Key::decode(input)?,
                ballot: Ballot::decode(input)?,
                val: Val::decode(input)?,
            },
            6 => Request::Ping,
            7 => Request::Read { key: Key::decode(input)?, from: ProposerId::decode(input)? },
            _ => return Err(CodecError::Invalid("Request tag")),
        })
    }
}

/// Response from an acceptor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    /// Prepare confirmation: the promise is persisted; carries the
    /// accepted (ballot, value) pair — (ZERO, Empty) if none yet.
    Promise {
        /// Ballot of the last accepted value (ZERO if none).
        accepted_ballot: Ballot,
        /// Last accepted value (Empty if none).
        accepted_val: Val,
    },
    /// Accept confirmation: the (ballot, value) pair is persisted.
    Accepted,
    /// The acceptor saw a greater ballot. Carries it so the proposer can
    /// fast-forward (§2.1).
    Conflict {
        /// The greater ballot the acceptor already promised/accepted.
        seen: Ballot,
    },
    /// The proposer's age is below the acceptor's minimum for it (§3.1).
    StaleAge {
        /// Minimum acceptable age recorded by the GC.
        required: u64,
    },
    /// Generic acknowledgement (SetMinAge, Erase, Install, Ping).
    Ok,
    /// Dump reply: a page of (key, accepted ballot, value) triples.
    DumpPage {
        /// The page.
        entries: Vec<(Key, Ballot, Val)>,
        /// True if more entries remain after the last one.
        more: bool,
    },
    /// The acceptor could not serve the request.
    Error(String),
    /// Quorum-read reply: a verbatim snapshot of the register's slot.
    /// Produced without any storage write — reads cost zero fsyncs.
    ReadState {
        /// Outstanding promise (ZERO if none): a promise above the
        /// accepted ballot signals a write in flight.
        promise: Ballot,
        /// Ballot of the accepted value (ZERO if none).
        accepted_ballot: Ballot,
        /// The accepted value (Empty if none).
        accepted_val: Val,
    },
}

impl Codec for Response {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Response::Promise { accepted_ballot, accepted_val } => {
                out.push(0);
                accepted_ballot.encode(out);
                accepted_val.encode(out);
            }
            Response::Accepted => out.push(1),
            Response::Conflict { seen } => {
                out.push(2);
                seen.encode(out);
            }
            Response::StaleAge { required } => {
                out.push(3);
                required.encode(out);
            }
            Response::Ok => out.push(4),
            Response::DumpPage { entries, more } => {
                out.push(5);
                encode_seq(entries, out);
                more.encode(out);
            }
            Response::Error(e) => {
                out.push(6);
                e.encode(out);
            }
            Response::ReadState { promise, accepted_ballot, accepted_val } => {
                out.push(7);
                promise.encode(out);
                accepted_ballot.encode(out);
                accepted_val.encode(out);
            }
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(match u8::decode(input)? {
            0 => Response::Promise {
                accepted_ballot: Ballot::decode(input)?,
                accepted_val: Val::decode(input)?,
            },
            1 => Response::Accepted,
            2 => Response::Conflict { seen: Ballot::decode(input)? },
            3 => Response::StaleAge { required: u64::decode(input)? },
            4 => Response::Ok,
            5 => Response::DumpPage { entries: decode_seq(input)?, more: bool::decode(input)? },
            6 => Response::Error(String::decode(input)?),
            7 => Response::ReadState {
                promise: Ballot::decode(input)?,
                accepted_ballot: Ballot::decode(input)?,
                accepted_val: Val::decode(input)?,
            },
            _ => return Err(CodecError::Invalid("Response tag")),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_roundtrip_requests() {
        let reqs = vec![
            Request::Prepare {
                key: "k".into(),
                ballot: Ballot::new(1, 2),
                from: ProposerId::new(2),
            },
            Request::Accept {
                key: "key/with/slash".into(),
                ballot: Ballot::new(1, 2),
                val: Val::Num { ver: 0, num: 7 },
                from: ProposerId { id: 2, age: 3 },
                promise_next: Some(Ballot::new(2, 2)),
            },
            Request::Accept {
                key: "k".into(),
                ballot: Ballot::new(1, 2),
                val: Val::Bytes { ver: 1, data: vec![0, 255] },
                from: ProposerId { id: 2, age: 3 },
                promise_next: None,
            },
            Request::SetMinAge { proposer_id: 1, min_age: 4 },
            Request::Erase { key: "k".into(), tombstone_ballot: Ballot::new(9, 1) },
            Request::Dump { after: Some("z".into()), limit: 10 },
            Request::Install { key: "k".into(), ballot: Ballot::new(3, 3), val: Val::Tombstone },
            Request::Ping,
            Request::Read { key: "k".into(), from: ProposerId { id: 7, age: 2 } },
        ];
        for r in reqs {
            assert_eq!(Request::from_bytes(&r.to_bytes()).unwrap(), r);
        }
    }

    #[test]
    fn codec_roundtrip_responses() {
        let resps = vec![
            Response::Promise { accepted_ballot: Ballot::ZERO, accepted_val: Val::Empty },
            Response::Accepted,
            Response::Conflict { seen: Ballot::new(5, 5) },
            Response::StaleAge { required: 2 },
            Response::Ok,
            Response::DumpPage {
                entries: vec![
                    ("a".into(), Ballot::ZERO, Val::Empty),
                    ("b".into(), Ballot::new(1, 1), Val::Num { ver: 0, num: 1 }),
                ],
                more: true,
            },
            Response::Error("boom".into()),
            Response::ReadState {
                promise: Ballot::new(4, 2),
                accepted_ballot: Ballot::new(3, 1),
                accepted_val: Val::Num { ver: 1, num: 9 },
            },
            Response::ReadState {
                promise: Ballot::ZERO,
                accepted_ballot: Ballot::ZERO,
                accepted_val: Val::Empty,
            },
        ];
        for r in resps {
            assert_eq!(Response::from_bytes(&r.to_bytes()).unwrap(), r);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::from_bytes(&[99]).is_err());
        assert!(Response::from_bytes(&[]).is_err());
        let mut bytes = Request::Ping.to_bytes();
        bytes.push(0);
        assert!(Request::from_bytes(&bytes).is_err(), "trailing bytes");
    }

    #[test]
    fn read_wire_types_reject_every_truncation() {
        // Every strict prefix of a valid encoding must fail to decode —
        // the frame layer depends on it to reject torn frames.
        let req =
            Request::Read { key: "key/with/slash".into(), from: ProposerId { id: 7, age: 2 } };
        let bytes = req.to_bytes();
        for cut in 0..bytes.len() {
            assert!(Request::from_bytes(&bytes[..cut]).is_err(), "prefix {cut} accepted");
        }
        let resp = Response::ReadState {
            promise: Ballot::new(9, 3),
            accepted_ballot: Ballot::new(8, 1),
            accepted_val: Val::Bytes { ver: 0, data: vec![1, 2, 3] },
        };
        let bytes = resp.to_bytes();
        for cut in 0..bytes.len() {
            assert!(Response::from_bytes(&bytes[..cut]).is_err(), "prefix {cut} accepted");
        }
    }

    #[test]
    fn read_request_rejects_length_bomb_key() {
        // Tag 7 (Read), then a key claiming 2^60 bytes with a tiny body.
        let mut bytes = vec![7u8];
        (1u64 << 60).encode(&mut bytes);
        bytes.extend_from_slice(b"k");
        assert!(Request::from_bytes(&bytes).is_err(), "length bomb accepted");
    }

    #[test]
    fn read_wire_types_reject_trailing_bytes() {
        let mut bytes =
            Request::Read { key: "k".into(), from: ProposerId::new(1) }.to_bytes();
        bytes.push(0);
        assert!(Request::from_bytes(&bytes).is_err(), "trailing bytes accepted");
    }

    #[test]
    fn request_key_accessor() {
        assert_eq!(
            Request::Prepare { key: "x".into(), ballot: Ballot::ZERO, from: ProposerId::new(0) }
                .key()
                .map(|s| s.as_str()),
            Some("x")
        );
        assert_eq!(Request::Ping.key(), None);
    }
}
