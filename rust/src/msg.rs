//! Wire protocol between proposers and acceptors.
//!
//! Two request/response pairs — prepare/promise and accept/accepted —
//! plus the admin messages the deletion GC (§3.1) and membership change
//! (§2.3) need. Every proposer message carries the proposer's *age* so
//! acceptors can reject messages from proposers that were alive before a
//! deletion was garbage-collected (the lost-delete anomaly guard).
//!
//! Messages implement the in-tree [`Codec`] (the wire format of the TCP
//! transport and the record format of the acceptor log).

use crate::ballot::Ballot;
use crate::codec::{decode_seq, encode_seq, Codec, CodecError};
use crate::state::Val;

/// Register key. Keys name independent CASPaxos instances (§3).
pub type Key = String;

/// Proposer identity + age, attached to every request (§3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProposerId {
    /// Numeric proposer id (ballot tiebreaker).
    pub id: u64,
    /// Age, incremented by the GC when it invalidates proposer caches.
    pub age: u64,
}

impl ProposerId {
    /// A proposer at age 0.
    pub fn new(id: u64) -> Self {
        ProposerId { id, age: 0 }
    }
}

impl Codec for ProposerId {
    fn encode(&self, out: &mut Vec<u8>) {
        self.id.encode(out);
        self.age.encode(out);
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(ProposerId { id: u64::decode(input)?, age: u64::decode(input)? })
    }
}

/// Request sent from a proposer to an acceptor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Prepare phase: "promise me ballot `ballot` on `key`".
    Prepare {
        /// Target register.
        key: Key,
        /// Ballot the proposer wants promised.
        ballot: Ballot,
        /// Sender identity + age.
        from: ProposerId,
    },
    /// Accept phase: "accept (`ballot`, `val`) on `key`".
    Accept {
        /// Target register.
        key: Key,
        /// Ballot from the prepare phase (or piggybacked 1-RTT ballot).
        ballot: Ballot,
        /// The new state produced by the change function.
        val: Val,
        /// Sender identity + age.
        from: ProposerId,
        /// One-round-trip optimization (§2.2.1): also promise the *next*
        /// ballot so the proposer can skip the next prepare phase.
        promise_next: Option<Ballot>,
    },
    /// GC step 2c (§3.1): require messages from proposer `proposer_id` to
    /// carry age ≥ `min_age`.
    SetMinAge {
        /// Proposer whose old incarnations must be rejected.
        proposer_id: u64,
        /// Minimum acceptable age.
        min_age: u64,
    },
    /// GC step 2d (§3.1): remove the register if it still holds the
    /// tombstone accepted at `tombstone_ballot`.
    Erase {
        /// Target register.
        key: Key,
        /// The ballot the tombstone was accepted at in GC step 2a.
        tombstone_ballot: Ballot,
    },
    /// Membership catch-up (§2.3.3): dump acceptor state for replication
    /// onto a fresh node. `after` allows incremental sync.
    Dump {
        /// Only keys lexicographically greater than this (None = all).
        after: Option<Key>,
        /// Max entries to return.
        limit: usize,
    },
    /// Membership catch-up: install a dumped slot if it is newer than the
    /// local one (conflict resolved by ballot, §2.3.3).
    Install {
        /// Register key.
        key: Key,
        /// Accepted ballot of the dumped slot.
        ballot: Ballot,
        /// Accepted value of the dumped slot.
        val: Val,
    },
    /// Liveness probe (used by examples and the TCP server).
    Ping,
    /// Quorum-read fast path: report the register's slot *without
    /// mutating or persisting anything*. The proposer serves the read in
    /// one round trip iff a read quorum reports a matching stable state
    /// (see `proposer::core::ReadCore`); otherwise it falls back to the
    /// classic identity-CAS round, so linearizability is never weakened.
    Read {
        /// Target register.
        key: Key,
        /// Sender identity + age (the GC fence applies to reads too).
        from: ProposerId,
    },
    /// Read-lease acquisition (0-RTT local reads): "promise me that for
    /// `duration_us` of *your* clock you will accept no foreign ballot
    /// on `key`". The grant is recorded in the register's slot and
    /// persisted (an acceptor that forgot a lease across a crash could
    /// let a foreign write slip past a still-serving leaseholder). The
    /// reply snapshots the slot, so an acquire round doubles as a read
    /// (see `proposer::core::LeaseCore`).
    LeaseAcquire {
        /// Target register.
        key: Key,
        /// Requested lease length, measured on the acceptor's clock
        /// from receipt (capped server-side).
        duration_us: u64,
        /// Requesting proposer (the lease holder candidate).
        from: ProposerId,
    },
    /// Lease renewal: identical acceptor semantics to `LeaseAcquire`
    /// (grant iff unleased, expired, or already held by `from`); kept
    /// as a distinct message so traces and counters can tell steady
    /// renewals from cold acquisitions.
    LeaseRenew {
        /// Target register.
        key: Key,
        /// Requested lease length (acceptor clock, from receipt).
        duration_us: u64,
        /// The current holder asking to extend.
        from: ProposerId,
    },
    /// Explicit lease release (membership change, failed partial
    /// acquisition): drop the lease iff `from` holds it. Only the
    /// holder can revoke — by then it has already stopped serving
    /// locally, so the release can never strand a stale fast path.
    LeaseRevoke {
        /// Target register.
        key: Key,
        /// The holder releasing its lease.
        from: ProposerId,
    },
}

impl Request {
    /// The register this request targets, if any.
    pub fn key(&self) -> Option<&Key> {
        match self {
            Request::Prepare { key, .. }
            | Request::Accept { key, .. }
            | Request::Erase { key, .. }
            | Request::Install { key, .. }
            | Request::Read { key, .. }
            | Request::LeaseAcquire { key, .. }
            | Request::LeaseRenew { key, .. }
            | Request::LeaseRevoke { key, .. } => Some(key),
            _ => None,
        }
    }
}

impl Codec for Request {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Request::Prepare { key, ballot, from } => {
                out.push(0);
                key.encode(out);
                ballot.encode(out);
                from.encode(out);
            }
            Request::Accept { key, ballot, val, from, promise_next } => {
                out.push(1);
                key.encode(out);
                ballot.encode(out);
                val.encode(out);
                from.encode(out);
                promise_next.encode(out);
            }
            Request::SetMinAge { proposer_id, min_age } => {
                out.push(2);
                proposer_id.encode(out);
                min_age.encode(out);
            }
            Request::Erase { key, tombstone_ballot } => {
                out.push(3);
                key.encode(out);
                tombstone_ballot.encode(out);
            }
            Request::Dump { after, limit } => {
                out.push(4);
                after.encode(out);
                limit.encode(out);
            }
            Request::Install { key, ballot, val } => {
                out.push(5);
                key.encode(out);
                ballot.encode(out);
                val.encode(out);
            }
            Request::Ping => out.push(6),
            Request::Read { key, from } => {
                out.push(7);
                key.encode(out);
                from.encode(out);
            }
            Request::LeaseAcquire { key, duration_us, from } => {
                out.push(8);
                key.encode(out);
                duration_us.encode(out);
                from.encode(out);
            }
            Request::LeaseRenew { key, duration_us, from } => {
                out.push(9);
                key.encode(out);
                duration_us.encode(out);
                from.encode(out);
            }
            Request::LeaseRevoke { key, from } => {
                out.push(10);
                key.encode(out);
                from.encode(out);
            }
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(match u8::decode(input)? {
            0 => Request::Prepare {
                key: Key::decode(input)?,
                ballot: Ballot::decode(input)?,
                from: ProposerId::decode(input)?,
            },
            1 => Request::Accept {
                key: Key::decode(input)?,
                ballot: Ballot::decode(input)?,
                val: Val::decode(input)?,
                from: ProposerId::decode(input)?,
                promise_next: Option::<Ballot>::decode(input)?,
            },
            2 => Request::SetMinAge {
                proposer_id: u64::decode(input)?,
                min_age: u64::decode(input)?,
            },
            3 => Request::Erase {
                key: Key::decode(input)?,
                tombstone_ballot: Ballot::decode(input)?,
            },
            4 => Request::Dump { after: Option::<Key>::decode(input)?, limit: usize::decode(input)? },
            5 => Request::Install {
                key: Key::decode(input)?,
                ballot: Ballot::decode(input)?,
                val: Val::decode(input)?,
            },
            6 => Request::Ping,
            7 => Request::Read { key: Key::decode(input)?, from: ProposerId::decode(input)? },
            8 => Request::LeaseAcquire {
                key: Key::decode(input)?,
                duration_us: u64::decode(input)?,
                from: ProposerId::decode(input)?,
            },
            9 => Request::LeaseRenew {
                key: Key::decode(input)?,
                duration_us: u64::decode(input)?,
                from: ProposerId::decode(input)?,
            },
            10 => Request::LeaseRevoke {
                key: Key::decode(input)?,
                from: ProposerId::decode(input)?,
            },
            _ => return Err(CodecError::Invalid("Request tag")),
        })
    }
}

/// Response from an acceptor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    /// Prepare confirmation: the promise is persisted; carries the
    /// accepted (ballot, value) pair — (ZERO, Empty) if none yet.
    Promise {
        /// Ballot of the last accepted value (ZERO if none).
        accepted_ballot: Ballot,
        /// Last accepted value (Empty if none).
        accepted_val: Val,
    },
    /// Accept confirmation: the (ballot, value) pair is persisted.
    Accepted,
    /// The acceptor saw a greater ballot. Carries it so the proposer can
    /// fast-forward (§2.1).
    Conflict {
        /// The greater ballot the acceptor already promised/accepted.
        seen: Ballot,
    },
    /// The proposer's age is below the acceptor's minimum for it (§3.1).
    StaleAge {
        /// Minimum acceptable age recorded by the GC.
        required: u64,
    },
    /// Generic acknowledgement (SetMinAge, Erase, Install, Ping).
    Ok,
    /// Dump reply: a page of (key, accepted ballot, value) triples.
    DumpPage {
        /// The page.
        entries: Vec<(Key, Ballot, Val)>,
        /// True if more entries remain after the last one.
        more: bool,
    },
    /// The acceptor could not serve the request.
    Error(String),
    /// Quorum-read reply: a verbatim snapshot of the register's slot.
    /// Produced without any storage write — reads cost zero fsyncs.
    ReadState {
        /// Outstanding promise (ZERO if none): a promise above the
        /// accepted ballot signals a write in flight.
        promise: Ballot,
        /// Ballot of the accepted value (ZERO if none).
        accepted_ballot: Ballot,
        /// The accepted value (Empty if none).
        accepted_val: Val,
    },
    /// Lease acquire/renew reply. `granted = false` means another
    /// proposer holds a live lease on the key. Either way the reply
    /// snapshots the slot (like `ReadState`), so the acquisition round
    /// can serve the read it was issued for without an extra phase. A
    /// `granted = true` reply is sent only after the lease record is
    /// durable (group-commit ticket waited).
    LeaseGranted {
        /// Whether the lease was granted/extended for the requester.
        granted: bool,
        /// Outstanding promise (ZERO if none).
        promise: Ballot,
        /// Ballot of the accepted value (ZERO if none).
        accepted_ballot: Ballot,
        /// The accepted value (Empty if none).
        accepted_val: Val,
        /// The proposer currently holding the lease, when the acceptor
        /// knows one: on a denial this names who to redirect the read
        /// to (the router's 0-RTT handoff), on a grant it echoes the
        /// requester. `None` when no live lease exists.
        holder: Option<u64>,
    },
}

impl Codec for Response {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Response::Promise { accepted_ballot, accepted_val } => {
                out.push(0);
                accepted_ballot.encode(out);
                accepted_val.encode(out);
            }
            Response::Accepted => out.push(1),
            Response::Conflict { seen } => {
                out.push(2);
                seen.encode(out);
            }
            Response::StaleAge { required } => {
                out.push(3);
                required.encode(out);
            }
            Response::Ok => out.push(4),
            Response::DumpPage { entries, more } => {
                out.push(5);
                encode_seq(entries, out);
                more.encode(out);
            }
            Response::Error(e) => {
                out.push(6);
                e.encode(out);
            }
            Response::ReadState { promise, accepted_ballot, accepted_val } => {
                out.push(7);
                promise.encode(out);
                accepted_ballot.encode(out);
                accepted_val.encode(out);
            }
            Response::LeaseGranted { granted, promise, accepted_ballot, accepted_val, holder } => {
                out.push(8);
                granted.encode(out);
                promise.encode(out);
                accepted_ballot.encode(out);
                accepted_val.encode(out);
                holder.encode(out);
            }
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(match u8::decode(input)? {
            0 => Response::Promise {
                accepted_ballot: Ballot::decode(input)?,
                accepted_val: Val::decode(input)?,
            },
            1 => Response::Accepted,
            2 => Response::Conflict { seen: Ballot::decode(input)? },
            3 => Response::StaleAge { required: u64::decode(input)? },
            4 => Response::Ok,
            5 => Response::DumpPage { entries: decode_seq(input)?, more: bool::decode(input)? },
            6 => Response::Error(String::decode(input)?),
            7 => Response::ReadState {
                promise: Ballot::decode(input)?,
                accepted_ballot: Ballot::decode(input)?,
                accepted_val: Val::decode(input)?,
            },
            8 => Response::LeaseGranted {
                granted: bool::decode(input)?,
                promise: Ballot::decode(input)?,
                accepted_ballot: Ballot::decode(input)?,
                accepted_val: Val::decode(input)?,
                holder: Option::<u64>::decode(input)?,
            },
            _ => return Err(CodecError::Invalid("Response tag")),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_roundtrip_requests() {
        let reqs = vec![
            Request::Prepare {
                key: "k".into(),
                ballot: Ballot::new(1, 2),
                from: ProposerId::new(2),
            },
            Request::Accept {
                key: "key/with/slash".into(),
                ballot: Ballot::new(1, 2),
                val: Val::Num { ver: 0, num: 7 },
                from: ProposerId { id: 2, age: 3 },
                promise_next: Some(Ballot::new(2, 2)),
            },
            Request::Accept {
                key: "k".into(),
                ballot: Ballot::new(1, 2),
                val: Val::Bytes { ver: 1, data: vec![0, 255] },
                from: ProposerId { id: 2, age: 3 },
                promise_next: None,
            },
            Request::SetMinAge { proposer_id: 1, min_age: 4 },
            Request::Erase { key: "k".into(), tombstone_ballot: Ballot::new(9, 1) },
            Request::Dump { after: Some("z".into()), limit: 10 },
            Request::Install { key: "k".into(), ballot: Ballot::new(3, 3), val: Val::Tombstone },
            Request::Ping,
            Request::Read { key: "k".into(), from: ProposerId { id: 7, age: 2 } },
            Request::LeaseAcquire {
                key: "k".into(),
                duration_us: 2_000_000,
                from: ProposerId { id: 7, age: 2 },
            },
            Request::LeaseRenew {
                key: "lease/key".into(),
                duration_us: u64::MAX,
                from: ProposerId::new(1),
            },
            Request::LeaseRevoke { key: "k".into(), from: ProposerId { id: 7, age: 2 } },
        ];
        for r in reqs {
            assert_eq!(Request::from_bytes(&r.to_bytes()).unwrap(), r);
        }
    }

    #[test]
    fn codec_roundtrip_responses() {
        let resps = vec![
            Response::Promise { accepted_ballot: Ballot::ZERO, accepted_val: Val::Empty },
            Response::Accepted,
            Response::Conflict { seen: Ballot::new(5, 5) },
            Response::StaleAge { required: 2 },
            Response::Ok,
            Response::DumpPage {
                entries: vec![
                    ("a".into(), Ballot::ZERO, Val::Empty),
                    ("b".into(), Ballot::new(1, 1), Val::Num { ver: 0, num: 1 }),
                ],
                more: true,
            },
            Response::Error("boom".into()),
            Response::ReadState {
                promise: Ballot::new(4, 2),
                accepted_ballot: Ballot::new(3, 1),
                accepted_val: Val::Num { ver: 1, num: 9 },
            },
            Response::ReadState {
                promise: Ballot::ZERO,
                accepted_ballot: Ballot::ZERO,
                accepted_val: Val::Empty,
            },
            Response::LeaseGranted {
                granted: true,
                promise: Ballot::new(4, 2),
                accepted_ballot: Ballot::new(3, 1),
                accepted_val: Val::Num { ver: 1, num: 9 },
                holder: Some(7),
            },
            Response::LeaseGranted {
                granted: false,
                promise: Ballot::ZERO,
                accepted_ballot: Ballot::ZERO,
                accepted_val: Val::Empty,
                holder: None,
            },
        ];
        for r in resps {
            assert_eq!(Response::from_bytes(&r.to_bytes()).unwrap(), r);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::from_bytes(&[99]).is_err());
        assert!(Response::from_bytes(&[]).is_err());
        let mut bytes = Request::Ping.to_bytes();
        bytes.push(0);
        assert!(Request::from_bytes(&bytes).is_err(), "trailing bytes");
    }

    #[test]
    fn read_wire_types_reject_every_truncation() {
        // Every strict prefix of a valid encoding must fail to decode —
        // the frame layer depends on it to reject torn frames.
        let req =
            Request::Read { key: "key/with/slash".into(), from: ProposerId { id: 7, age: 2 } };
        let bytes = req.to_bytes();
        for cut in 0..bytes.len() {
            assert!(Request::from_bytes(&bytes[..cut]).is_err(), "prefix {cut} accepted");
        }
        let resp = Response::ReadState {
            promise: Ballot::new(9, 3),
            accepted_ballot: Ballot::new(8, 1),
            accepted_val: Val::Bytes { ver: 0, data: vec![1, 2, 3] },
        };
        let bytes = resp.to_bytes();
        for cut in 0..bytes.len() {
            assert!(Response::from_bytes(&bytes[..cut]).is_err(), "prefix {cut} accepted");
        }
    }

    #[test]
    fn read_request_rejects_length_bomb_key() {
        // Tag 7 (Read), then a key claiming 2^60 bytes with a tiny body.
        let mut bytes = vec![7u8];
        (1u64 << 60).encode(&mut bytes);
        bytes.extend_from_slice(b"k");
        assert!(Request::from_bytes(&bytes).is_err(), "length bomb accepted");
    }

    #[test]
    fn read_wire_types_reject_trailing_bytes() {
        let mut bytes =
            Request::Read { key: "k".into(), from: ProposerId::new(1) }.to_bytes();
        bytes.push(0);
        assert!(Request::from_bytes(&bytes).is_err(), "trailing bytes accepted");
    }

    #[test]
    fn lease_wire_types_reject_every_truncation() {
        // Same pin the Read/ReadState pair carries: every strict prefix
        // of a valid encoding must fail to decode, or the frame layer
        // would accept torn frames.
        let msgs = vec![
            Request::LeaseAcquire {
                key: "key/with/slash".into(),
                duration_us: 5_000_000,
                from: ProposerId { id: 7, age: 2 },
            },
            Request::LeaseRenew {
                key: "k".into(),
                duration_us: 1,
                from: ProposerId::new(3),
            },
            Request::LeaseRevoke { key: "kk".into(), from: ProposerId { id: 9, age: 1 } },
        ];
        for msg in msgs {
            let bytes = msg.to_bytes();
            for cut in 0..bytes.len() {
                assert!(Request::from_bytes(&bytes[..cut]).is_err(), "prefix {cut} accepted");
            }
        }
        let resp = Response::LeaseGranted {
            granted: true,
            promise: Ballot::new(9, 3),
            accepted_ballot: Ballot::new(8, 1),
            accepted_val: Val::Bytes { ver: 0, data: vec![1, 2, 3] },
            holder: Some(7),
        };
        let bytes = resp.to_bytes();
        for cut in 0..bytes.len() {
            assert!(Response::from_bytes(&bytes[..cut]).is_err(), "prefix {cut} accepted");
        }
    }

    #[test]
    fn lease_requests_reject_length_bomb_key() {
        // Tags 8/9/10 (lease messages), then a key claiming 2^60 bytes
        // with a tiny body — must be rejected before any allocation.
        for tag in [8u8, 9, 10] {
            let mut bytes = vec![tag];
            (1u64 << 60).encode(&mut bytes);
            bytes.extend_from_slice(b"k");
            assert!(Request::from_bytes(&bytes).is_err(), "tag {tag} length bomb accepted");
        }
    }

    #[test]
    fn lease_wire_types_reject_trailing_bytes() {
        let mut bytes = Request::LeaseAcquire {
            key: "k".into(),
            duration_us: 7,
            from: ProposerId::new(1),
        }
        .to_bytes();
        bytes.push(0);
        assert!(Request::from_bytes(&bytes).is_err(), "trailing bytes accepted");
        let mut bytes = Response::LeaseGranted {
            granted: false,
            promise: Ballot::ZERO,
            accepted_ballot: Ballot::ZERO,
            accepted_val: Val::Empty,
            holder: None,
        }
        .to_bytes();
        bytes.push(1);
        assert!(Response::from_bytes(&bytes).is_err(), "trailing bytes accepted");
    }

    #[test]
    fn lease_wire_fuzz_roundtrip_and_truncation() {
        // Seeded fuzz over the whole lease message space: every encode
        // must roundtrip exactly, every strict prefix must be rejected,
        // and decoding never panics (forall_seeds re-raises with the
        // replay seed on failure).
        crate::testkit::forall_seeds(0x1EA5E, 64, |rng| {
            let key_len = rng.gen_range(24) as usize;
            let key: Key =
                (0..key_len).map(|_| (b'a' + rng.gen_range(26) as u8) as char).collect();
            let from = ProposerId { id: rng.next_u64(), age: rng.next_u64() };
            let duration_us = rng.next_u64();
            let req = match rng.gen_range(3) {
                0 => Request::LeaseAcquire { key: key.clone(), duration_us, from },
                1 => Request::LeaseRenew { key: key.clone(), duration_us, from },
                _ => Request::LeaseRevoke { key: key.clone(), from },
            };
            let bytes = req.to_bytes();
            assert_eq!(Request::from_bytes(&bytes).unwrap(), req);
            for cut in 0..bytes.len() {
                assert!(Request::from_bytes(&bytes[..cut]).is_err(), "prefix {cut} accepted");
            }
            let resp = Response::LeaseGranted {
                granted: rng.gen_range(2) == 0,
                promise: Ballot::new(rng.next_u64(), rng.next_u64()),
                accepted_ballot: Ballot::new(rng.next_u64(), rng.next_u64()),
                accepted_val: match rng.gen_range(3) {
                    0 => Val::Empty,
                    1 => Val::Num { ver: rng.next_u64() as i64, num: rng.next_u64() as i64 },
                    _ => Val::Bytes {
                        ver: rng.gen_range(100) as i64,
                        data: (0..rng.gen_range(16)).map(|_| rng.next_u64() as u8).collect(),
                    },
                },
                holder: if rng.gen_range(2) == 0 { Some(rng.next_u64()) } else { None },
            };
            let bytes = resp.to_bytes();
            assert_eq!(Response::from_bytes(&bytes).unwrap(), resp);
            for cut in 0..bytes.len() {
                assert!(Response::from_bytes(&bytes[..cut]).is_err(), "prefix {cut} accepted");
            }
        });
    }

    #[test]
    fn enveloped_wire_types_reject_every_truncation() {
        // The pipelined TCP transport frames every Request/Response in a
        // correlation-id envelope; a torn envelope frame must fail to
        // decode at EVERY strict prefix or the demux could mis-deliver.
        use crate::codec::Envelope;
        let req = Envelope {
            corr: 0xDEAD_BEEF_u64,
            body: Request::Accept {
                key: "key/with/slash".into(),
                ballot: Ballot::new(3, 2),
                val: Val::Bytes { ver: 1, data: vec![0, 255, 7] },
                from: ProposerId { id: 2, age: 3 },
                promise_next: Some(Ballot::new(4, 2)),
            },
        };
        let bytes = req.to_bytes();
        assert_eq!(Envelope::<Request>::from_bytes(&bytes).unwrap(), req);
        for cut in 0..bytes.len() {
            assert!(
                Envelope::<Request>::from_bytes(&bytes[..cut]).is_err(),
                "prefix {cut} accepted"
            );
        }
        let resp = Envelope {
            corr: 1,
            body: Response::ReadState {
                promise: Ballot::new(9, 3),
                accepted_ballot: Ballot::new(8, 1),
                accepted_val: Val::Num { ver: 2, num: -9 },
            },
        };
        let bytes = resp.to_bytes();
        assert_eq!(Envelope::<Response>::from_bytes(&bytes).unwrap(), resp);
        for cut in 0..bytes.len() {
            assert!(
                Envelope::<Response>::from_bytes(&bytes[..cut]).is_err(),
                "prefix {cut} accepted"
            );
        }
    }

    #[test]
    fn enveloped_request_rejects_length_bomb_key() {
        // corr id, tag 0 (Prepare), then a key claiming 2^60 bytes with
        // a tiny body — must be rejected before any allocation.
        use crate::codec::Envelope;
        let mut bytes = Vec::new();
        42u64.encode(&mut bytes);
        bytes.push(0u8);
        (1u64 << 60).encode(&mut bytes);
        bytes.extend_from_slice(b"k");
        assert!(Envelope::<Request>::from_bytes(&bytes).is_err(), "length bomb accepted");
    }

    #[test]
    fn envelope_wire_fuzz_roundtrip_and_truncation() {
        // Seeded fuzz over enveloped requests/responses: every encode
        // must roundtrip exactly (corr id included), every strict prefix
        // must be rejected, and decoding never panics.
        use crate::codec::Envelope;
        crate::testkit::forall_seeds(0xC0_11E1A7E, 64, |rng| {
            let key_len = rng.gen_range(24) as usize;
            let key: Key =
                (0..key_len).map(|_| (b'a' + rng.gen_range(26) as u8) as char).collect();
            let from = ProposerId { id: rng.next_u64(), age: rng.next_u64() };
            let body = match rng.gen_range(4) {
                0 => Request::Prepare {
                    key,
                    ballot: Ballot::new(rng.next_u64(), rng.next_u64()),
                    from,
                },
                1 => Request::Read { key, from },
                2 => Request::LeaseAcquire { key, duration_us: rng.next_u64(), from },
                _ => Request::Ping,
            };
            let req = Envelope { corr: rng.next_u64(), body };
            let bytes = req.to_bytes();
            assert_eq!(Envelope::<Request>::from_bytes(&bytes).unwrap(), req);
            for cut in 0..bytes.len() {
                assert!(
                    Envelope::<Request>::from_bytes(&bytes[..cut]).is_err(),
                    "prefix {cut} accepted"
                );
            }
            let body = match rng.gen_range(4) {
                0 => Response::Accepted,
                1 => Response::Conflict {
                    seen: Ballot::new(rng.next_u64(), rng.next_u64()),
                },
                2 => Response::ReadState {
                    promise: Ballot::new(rng.next_u64(), rng.next_u64()),
                    accepted_ballot: Ballot::new(rng.next_u64(), rng.next_u64()),
                    accepted_val: Val::Num {
                        ver: rng.next_u64() as i64,
                        num: rng.next_u64() as i64,
                    },
                },
                _ => Response::Error("boom".into()),
            };
            let resp = Envelope { corr: rng.next_u64(), body };
            let bytes = resp.to_bytes();
            assert_eq!(Envelope::<Response>::from_bytes(&bytes).unwrap(), resp);
            for cut in 0..bytes.len() {
                assert!(
                    Envelope::<Response>::from_bytes(&bytes[..cut]).is_err(),
                    "prefix {cut} accepted"
                );
            }
        });
    }

    #[test]
    fn request_key_accessor() {
        assert_eq!(
            Request::Prepare { key: "x".into(), ballot: Ballot::ZERO, from: ProposerId::new(0) }
                .key()
                .map(|s| s.as_str()),
            Some("x")
        );
        assert_eq!(
            Request::LeaseAcquire { key: "l".into(), duration_us: 1, from: ProposerId::new(0) }
                .key()
                .map(|s| s.as_str()),
            Some("l")
        );
        assert_eq!(Request::Ping.key(), None);
    }
}
