//! Compact binary wire/storage codec.
//!
//! The offline dependency set has no serde, so the crate carries its own
//! explicit codec: little-endian fixed-width integers, length-prefixed
//! strings/byte-vectors, one tag byte per enum variant. Every protocol
//! type implements [`Codec`] by hand next to its definition; this module
//! provides the trait, the primitive impls and the framing helpers.
//!
//! Properties the tests pin down: encode∘decode = id, decode rejects
//! truncated input, and frames are bounded (no length-bomb allocations).

/// Decoding error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecError {
    /// Input ended mid-value.
    Eof,
    /// Malformed content (bad tag, bad UTF-8, length bomb...).
    Invalid(&'static str),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Eof => write!(f, "unexpected end of input"),
            CodecError::Invalid(what) => write!(f, "invalid encoding: {what}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// Maximum length accepted for any string/vec/map (guards length bombs).
pub const MAX_LEN: usize = 1 << 24; // 16 MiB

/// Binary encode/decode. Implementations must round-trip exactly.
pub trait Codec: Sized {
    /// Appends the encoding of `self` to `out`.
    fn encode(&self, out: &mut Vec<u8>);
    /// Decodes a value from the front of `input`, advancing it.
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError>;

    /// Encodes into a fresh buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32);
        self.encode(&mut out);
        out
    }

    /// Decodes a complete buffer; trailing bytes are an error.
    fn from_bytes(mut input: &[u8]) -> Result<Self, CodecError> {
        let v = Self::decode(&mut input)?;
        if !input.is_empty() {
            return Err(CodecError::Invalid("trailing bytes"));
        }
        Ok(v)
    }
}

pub(crate) fn take<'a>(input: &mut &'a [u8], n: usize) -> Result<&'a [u8], CodecError> {
    if input.len() < n {
        return Err(CodecError::Eof);
    }
    let (head, rest) = input.split_at(n);
    *input = rest;
    Ok(head)
}

macro_rules! int_codec {
    ($($t:ty),*) => {$(
        impl Codec for $t {
            fn encode(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
                let bytes = take(input, std::mem::size_of::<$t>())?;
                Ok(<$t>::from_le_bytes(bytes.try_into().unwrap()))
            }
        }
    )*};
}

int_codec!(u8, u16, u32, u64, i32, i64, f64);

impl Codec for bool {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(*self as u8);
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        match u8::decode(input)? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(CodecError::Invalid("bool tag")),
        }
    }
}

impl Codec for usize {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u64).encode(out);
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        let v = u64::decode(input)?;
        usize::try_from(v).map_err(|_| CodecError::Invalid("usize overflow"))
    }
}

fn decode_len(input: &mut &[u8]) -> Result<usize, CodecError> {
    let n = usize::decode(input)?;
    if n > MAX_LEN {
        return Err(CodecError::Invalid("length bomb"));
    }
    Ok(n)
}

impl Codec for String {
    fn encode(&self, out: &mut Vec<u8>) {
        self.len().encode(out);
        out.extend_from_slice(self.as_bytes());
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        let n = decode_len(input)?;
        let bytes = take(input, n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CodecError::Invalid("utf-8"))
    }
}

impl Codec for Vec<u8> {
    fn encode(&self, out: &mut Vec<u8>) {
        self.len().encode(out);
        out.extend_from_slice(self);
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        let n = decode_len(input)?;
        Ok(take(input, n)?.to_vec())
    }
}

impl<T: Codec> Codec for Option<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(v) => {
                out.push(1);
                v.encode(out);
            }
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        match u8::decode(input)? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(input)?)),
            _ => Err(CodecError::Invalid("option tag")),
        }
    }
}

// Not for Vec<u8> (owned above); used via explicit helpers to avoid
// overlapping impls.
/// Encodes a slice of codec values with a length prefix.
pub fn encode_seq<T: Codec>(items: &[T], out: &mut Vec<u8>) {
    items.len().encode(out);
    for item in items {
        item.encode(out);
    }
}

/// Decodes a length-prefixed sequence.
pub fn decode_seq<T: Codec>(input: &mut &[u8]) -> Result<Vec<T>, CodecError> {
    let n = decode_len(input)?;
    // Conservative pre-allocation: avoid length-bomb allocs for nested
    // sequences whose element size we can't know here.
    let mut items = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        items.push(T::decode(input)?);
    }
    Ok(items)
}

/// Correlation-id envelope wrapping every frame of the pipelined TCP
/// protocols (acceptor *and* client service).
///
/// A connection carries many requests concurrently; replies may come
/// back **in any order** (a read overtakes a write stalled on its
/// group-commit fsync). `corr` is what matches a reply to its request:
/// the requester picks a connection-unique id, the responder echoes it
/// verbatim. Ids carry no ordering semantics — only equality matters —
/// and a reply with an unknown or already-answered id is dropped by the
/// receiver (late replies after a timeout sweep look exactly like
/// that).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Envelope<T> {
    /// Correlation id, echoed verbatim on the reply.
    pub corr: u64,
    /// The enveloped message.
    pub body: T,
}

/// Appends `corr` + `body` exactly as [`Envelope::encode`] does — the
/// borrowed-body twin for write paths that frame a message they don't
/// own. THE single statement of the envelope layout: `Envelope`'s
/// `Codec` impl delegates here, so the two can never diverge.
pub fn encode_envelope<T: Codec>(corr: u64, body: &T, out: &mut Vec<u8>) {
    corr.encode(out);
    body.encode(out);
}

impl<T: Codec> Codec for Envelope<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        encode_envelope(self.corr, &self.body, out);
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(Envelope { corr: u64::decode(input)?, body: T::decode(input)? })
    }
}

impl<A: Codec, B: Codec> Codec for (A, B) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok((A::decode(input)?, B::decode(input)?))
    }
}

impl<A: Codec, B: Codec, C: Codec> Codec for (A, B, C) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
        self.2.encode(out);
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok((A::decode(input)?, B::decode(input)?, C::decode(input)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Codec + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = v.to_bytes();
        assert_eq!(T::from_bytes(&bytes).unwrap(), v);
    }

    #[test]
    fn primitives_roundtrip() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(u32::MAX);
        roundtrip(u64::MAX);
        roundtrip(i64::MIN);
        roundtrip(-1i32);
        roundtrip(true);
        roundtrip(3.25f64);
        roundtrip(usize::MAX >> 1);
        roundtrip(String::from("hello ∅ ⊥ unicode"));
        roundtrip(String::new());
        roundtrip(vec![0u8, 1, 255]);
        roundtrip(Option::<u64>::None);
        roundtrip(Some(42u64));
        roundtrip((7u64, String::from("x")));
        roundtrip((1u8, 2u32, 3i64));
    }

    #[test]
    fn seq_roundtrip() {
        let items = vec![(1u64, "a".to_string()), (2, "b".to_string())];
        let mut out = Vec::new();
        encode_seq(&items, &mut out);
        let mut input = out.as_slice();
        let back: Vec<(u64, String)> = decode_seq(&mut input).unwrap();
        assert_eq!(back, items);
        assert!(input.is_empty());
    }

    #[test]
    fn truncated_input_is_eof() {
        let bytes = 12345u64.to_bytes();
        assert_eq!(u64::from_bytes(&bytes[..4]), Err(CodecError::Eof));
        let s = "hello".to_string().to_bytes();
        assert_eq!(String::from_bytes(&s[..6]), Err(CodecError::Eof));
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = 1u8.to_bytes();
        bytes.push(9);
        assert!(matches!(u8::from_bytes(&bytes), Err(CodecError::Invalid(_))));
    }

    #[test]
    fn bad_tags_rejected() {
        assert!(matches!(bool::from_bytes(&[2]), Err(CodecError::Invalid(_))));
        assert!(matches!(Option::<u8>::from_bytes(&[7]), Err(CodecError::Invalid(_))));
    }

    #[test]
    fn length_bomb_rejected() {
        // Claims a 2^60-byte string with a 1-byte body.
        let mut bytes = Vec::new();
        (1u64 << 60).encode(&mut bytes);
        bytes.push(b'x');
        assert!(matches!(String::from_bytes(&bytes), Err(CodecError::Invalid(_))));
    }

    #[test]
    fn envelope_roundtrip_and_truncation() {
        let env = Envelope { corr: u64::MAX, body: "payload".to_string() };
        let bytes = env.to_bytes();
        assert_eq!(Envelope::<String>::from_bytes(&bytes).unwrap(), env);
        // Every strict prefix must fail: the frame layer depends on it
        // to reject torn frames.
        for cut in 0..bytes.len() {
            assert!(Envelope::<String>::from_bytes(&bytes[..cut]).is_err(), "prefix {cut}");
        }
        let mut bytes = env.to_bytes();
        bytes.push(0);
        assert!(Envelope::<String>::from_bytes(&bytes).is_err(), "trailing bytes accepted");
    }

    #[test]
    fn envelope_length_bomb_rejected() {
        // corr, then a body claiming 2^60 bytes with a tiny payload.
        let mut bytes = Vec::new();
        7u64.encode(&mut bytes);
        (1u64 << 60).encode(&mut bytes);
        bytes.push(b'x');
        assert!(matches!(Envelope::<String>::from_bytes(&bytes), Err(CodecError::Invalid(_))));
    }

    #[test]
    fn bad_utf8_rejected() {
        let mut bytes = Vec::new();
        2usize.encode(&mut bytes);
        bytes.extend_from_slice(&[0xff, 0xfe]);
        assert!(matches!(String::from_bytes(&bytes), Err(CodecError::Invalid(_))));
    }
}
