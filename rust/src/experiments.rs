//! Reusable drivers for the paper's evaluation experiments (E2, E3).
//!
//! Both the runnable examples and the `cargo bench` targets call these,
//! so the tables are regenerated from exactly one implementation.

use std::sync::Arc;

use crate::baselines::leaderlog::{LlClient, LlConfig, LlMsg, LlReplica};
use crate::baselines::profiles;
use crate::quorum::ClusterConfig;
use crate::sim::cas::{AcceptorActor, CasMsg, ClientActor, ClientStats, Workload};
use crate::sim::{Region, SimTime, World};
use crate::wan::{self, REGION_NAMES};

/// One row of the §3.2 latency table.
#[derive(Debug, Clone)]
pub struct LatencyRow {
    /// System name (MongoDB / Etcd / Gryadka).
    pub system: &'static str,
    /// Client region name.
    pub region: &'static str,
    /// Latency the paper measured (ms).
    pub paper_ms: f64,
    /// Latency our simulation measured (ms).
    pub measured_ms: f64,
}

/// The paper's measured §3.2 latencies (ms), indexed [system][region]
/// with systems = [MongoDB, Etcd, Gryadka].
pub const PAPER_LATENCY_MS: [[f64; 3]; 3] =
    [[1086.0, 1168.0, 739.0], [679.0, 718.0, 339.0], [47.0, 47.0, 356.0]];

/// Runs the CASPaxos (Gryadka) side of E2: one acceptor per region, one
/// colocated RMW client per region, paper RTT matrix. Returns mean
/// iteration latency (ms) per region.
pub fn gryadka_wan_latency(iterations: u64, seed: u64) -> [f64; 3] {
    let mut world: World<CasMsg> = World::new(wan::azure_net(), seed);
    // Acceptors 1..=3 at regions 0..=2.
    for r in 0..3u64 {
        world.add_node(r + 1, Region(r as usize), Box::new(AcceptorActor::new(r + 1)));
    }
    let cfg = ClusterConfig::majority(1, vec![1, 2, 3]);
    let mut stats: Vec<Arc<ClientStats>> = Vec::new();
    for r in 0..3u64 {
        let (client, s) = ClientActor::new(
            100 + r,
            format!("key-region-{r}"), // "All clients used their keys"
            Workload::ReadModifyWrite,
            cfg.clone(),
            iterations,
        );
        world.add_node(100 + r, Region(r as usize), Box::new(client));
        stats.push(s);
    }
    world.start();
    world.run_until(1_000_000_000); // 1000 virtual seconds >> workload
    [stats[0].mean_latency_ms(), stats[1].mean_latency_ms(), stats[2].mean_latency_ms()]
}

/// Runs a leader-based system (E2 comparators): replicas in all three
/// regions, leader pinned in Southeast Asia (as it happened in the
/// paper's experiment), one colocated RMW client per region.
pub fn leaderlog_wan_latency(cfg: LlConfig, iterations: u64, seed: u64) -> [f64; 3] {
    let mut world: World<LlMsg> = World::new(wan::azure_net(), seed);
    for r in 0..3u64 {
        world.add_node(r + 1, Region(r as usize), Box::new(LlReplica::new(r + 1, cfg.clone())));
    }
    let mut stats: Vec<Arc<ClientStats>> = Vec::new();
    for r in 0..3u64 {
        let (client, s) = LlClient::new(format!("key-region-{r}"), r + 1, iterations);
        world.add_node(100 + r, Region(r as usize), Box::new(client));
        stats.push(s);
    }
    world.start();
    world.run_until(1_000_000_000);
    [stats[0].mean_latency_ms(), stats[1].mean_latency_ms(), stats[2].mean_latency_ms()]
}

/// Regenerates the full §3.2 latency table (E2).
pub fn wan_latency_table(iterations: u64, seed: u64) -> Vec<LatencyRow> {
    // Leader in Southeast Asia = node 3.
    let mongo = leaderlog_wan_latency(profiles::mongo_like(vec![1, 2, 3], 3), iterations, seed);
    let etcd = leaderlog_wan_latency(profiles::etcd_like(vec![1, 2, 3], 3), iterations, seed);
    let gryadka = gryadka_wan_latency(iterations, seed);
    let mut rows = Vec::new();
    for (sys_idx, (system, measured)) in
        [("MongoDB", mongo), ("Etcd", etcd), ("Gryadka", gryadka)].into_iter().enumerate()
    {
        for r in 0..3 {
            rows.push(LatencyRow {
                system,
                region: REGION_NAMES[r],
                paper_ms: PAPER_LATENCY_MS[sys_idx][r],
                measured_ms: measured[r],
            });
        }
    }
    rows
}

/// One row of the §3.3 unavailability table.
#[derive(Debug, Clone)]
pub struct UnavailabilityRow {
    /// Database name.
    pub system: &'static str,
    /// Replication protocol label.
    pub protocol: &'static str,
    /// Window the paper measured (s).
    pub paper_s: f64,
    /// Window our simulation measured (s).
    pub measured_s: f64,
}

/// Time at which the leader is isolated (µs of virtual time).
pub const ISOLATE_AT: SimTime = 30_000_000;
/// End of the measurement window (µs).
pub const MEASURE_UNTIL: SimTime = 120_000_000;

/// Measures the §3.3 leader-isolation unavailability window for one
/// leader-based profile: isolate the leader at [`ISOLATE_AT`], report
/// the largest gap between successful client iterations afterwards,
/// minus the workload's natural iteration latency.
pub fn leaderlog_unavailability(cfg: LlConfig, seed: u64) -> f64 {
    let mut world: World<LlMsg> = World::new(wan::azure_net(), seed);
    for r in 0..3u64 {
        world.add_node(r + 1, Region(r as usize), Box::new(LlReplica::new(r + 1, cfg.clone())));
    }
    // One client colocated with a NON-leader replica (the leader node is
    // about to fall off the network).
    let (client, stats) = LlClient::new("k", 1, u64::MAX);
    world.add_node(100, Region(0), Box::new(client));
    world.start();
    world.run_until(ISOLATE_AT);
    world.isolate(3); // the Southeast Asia leader
    world.run_until(MEASURE_UNTIL);
    let healthy_iter = baseline_gap(&stats, ISOLATE_AT);
    let gap = stats.max_gap_in(ISOLATE_AT, MEASURE_UNTIL);
    (gap.saturating_sub(healthy_iter)) as f64 / 1e6
}

/// Measures the same accident for CASPaxos/Gryadka: isolate one acceptor
/// (there is no leader; by symmetry any node is "the" node).
pub fn gryadka_unavailability(seed: u64) -> f64 {
    let mut world: World<CasMsg> = World::new(wan::azure_net(), seed);
    for r in 0..3u64 {
        world.add_node(r + 1, Region(r as usize), Box::new(AcceptorActor::new(r + 1)));
    }
    let cfg = ClusterConfig::majority(1, vec![1, 2, 3]);
    let (client, stats) =
        ClientActor::new(100, "k", Workload::ReadModifyWrite, cfg, u64::MAX);
    let client = client.with_round_timeout(1_000_000);
    world.add_node(100, Region(0), Box::new(client));
    world.start();
    world.run_until(ISOLATE_AT);
    world.isolate(3);
    world.run_until(MEASURE_UNTIL);
    let healthy_iter = baseline_gap(&stats, ISOLATE_AT);
    let gap = stats.max_gap_in(ISOLATE_AT, MEASURE_UNTIL);
    (gap.saturating_sub(healthy_iter)) as f64 / 1e6
}

/// The workload's largest healthy-phase gap (its natural per-iteration
/// latency), used to normalize the outage measurement.
fn baseline_gap(stats: &ClientStats, until: SimTime) -> SimTime {
    stats.max_gap_in(1_000_000, until) // skip the cold start
}

/// Regenerates the full §3.3 unavailability table (E3).
pub fn unavailability_table(seed: u64) -> Vec<UnavailabilityRow> {
    let mut rows = vec![UnavailabilityRow {
        system: profiles::GRYADKA.name,
        protocol: profiles::GRYADKA.protocol,
        paper_s: profiles::GRYADKA.paper_window_s,
        measured_s: gryadka_unavailability(seed),
    }];
    for p in &profiles::LEADER_BASED {
        let cfg = profiles::ll_config(p, vec![1, 2, 3], 3);
        rows.push(UnavailabilityRow {
            system: p.name,
            protocol: p.protocol,
            paper_s: p.paper_window_s,
            measured_s: leaderlog_unavailability(cfg, seed),
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gryadka_latency_matches_paper_shape() {
        let [wus2, wcus, sea] = gryadka_wan_latency(20, 7);
        // Paper estimates: 43.6 / 43.6 / 338 ms. Allow sim jitter.
        assert!((40.0..60.0).contains(&wus2), "West US 2: {wus2}ms");
        assert!((40.0..60.0).contains(&wcus), "West Central US: {wcus}ms");
        assert!((300.0..400.0).contains(&sea), "Southeast Asia: {sea}ms");
    }

    #[test]
    fn etcd_like_latency_matches_paper_shape() {
        let cfg = profiles::etcd_like(vec![1, 2, 3], 3);
        let [wus2, wcus, sea] = leaderlog_wan_latency(cfg, 20, 7);
        // Paper estimates: 676 / 716 / 338 ms.
        assert!((600.0..760.0).contains(&wus2), "West US 2: {wus2}ms");
        assert!((650.0..800.0).contains(&wcus), "West Central US: {wcus}ms");
        assert!((300.0..420.0).contains(&sea), "Southeast Asia: {sea}ms");
    }

    #[test]
    fn leaderless_beats_leader_based_off_leader_regions() {
        let rows = wan_latency_table(15, 3);
        let get = |sys: &str, reg: &str| {
            rows.iter()
                .find(|r| r.system == sys && r.region == reg)
                .map(|r| r.measured_ms)
                .unwrap()
        };
        // The paper's qualitative claims:
        // 1. Gryadka is ~an order of magnitude faster in US regions.
        assert!(get("Gryadka", "West US 2") * 5.0 < get("Etcd", "West US 2"));
        assert!(get("Gryadka", "West Central US") * 5.0 < get("Etcd", "West Central US"));
        // 2. In the leader's region the two are comparable.
        let ratio = get("Gryadka", "Southeast Asia") / get("Etcd", "Southeast Asia");
        assert!((0.5..2.0).contains(&ratio), "SEA ratio {ratio}");
        // 3. MongoDB is the slowest everywhere (processing overhead).
        assert!(get("MongoDB", "West US 2") > get("Etcd", "West US 2"));
    }

    #[test]
    fn unavailability_shape_matches_paper() {
        let rows = unavailability_table(11);
        let gryadka = rows.iter().find(|r| r.system == "Gryadka").unwrap();
        assert!(
            gryadka.measured_s < 1.5,
            "CASPaxos outage should be ~0 (sub-round-timeout), got {}s",
            gryadka.measured_s
        );
        for r in rows.iter().filter(|r| r.system != "Gryadka") {
            assert!(
                r.measured_s > 0.5,
                "{} should show a seconds-scale outage, got {}s",
                r.system,
                r.measured_s
            );
            // Within ~4x of the paper's measured window (it's a timeout
            // configuration, not a precise quantity).
            assert!(
                r.measured_s < r.paper_s * 4.0 + 2.0,
                "{}: {}s vs paper {}s",
                r.system,
                r.measured_s,
                r.paper_s
            );
        }
    }
}
