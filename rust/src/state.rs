//! Register state (§2.2).
//!
//! A CASPaxos register holds an arbitrary value; this implementation
//! supports a kernel-friendly versioned numeric payload (what the paper's
//! §3.2 read-modify-write workload uses and what the L1 Pallas kernel
//! operates on) and a general versioned byte payload, plus the two special
//! states the protocol needs: *empty* (∅ — never written) and *tombstone*
//! (deleted, pending GC — §3.1).

use crate::codec::{Codec, CodecError};

/// Op-code values shared with the L1 kernel (see
/// `python/compile/kernels/apply_cas.py`). Kept in one place so the Rust
/// scalar path and the Pallas kernel can be differential-tested.
pub mod opcode {
    /// `x -> x` (read / rescan / identity transition).
    pub const READ: i32 = 0;
    /// `x -> if x = ∅ then (0, arg) else x`.
    pub const INIT: i32 = 1;
    /// `x -> if x.ver = expected then (expected+1, arg) else x` (reject).
    pub const CAS: i32 = 2;
    /// `x -> (x.ver+1, arg)` unconditional overwrite.
    pub const SET: i32 = 3;
    /// `x -> (x.ver+1, x.num + arg)`; treats ∅ as 0 (the §3.2 increment).
    pub const ADD: i32 = 4;
    /// `x -> tombstone` (delete, §3.1).
    pub const TOMBSTONE: i32 = 5;
}

/// The value stored in a register.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub enum Val {
    /// ∅ — the register was never written.
    #[default]
    Empty,
    /// Deleted; retained until the GC process removes the register.
    Tombstone,
    /// Versioned numeric payload (kernel fast path).
    Num {
        /// CAS version, bumped on every successful mutation.
        ver: i64,
        /// The number itself.
        num: i64,
    },
    /// Versioned opaque payload (general path).
    Bytes {
        /// CAS version, bumped on every successful mutation.
        ver: i64,
        /// The payload.
        data: Vec<u8>,
    },
}

impl Val {
    /// Numeric payload if this is a `Num`.
    pub fn as_num(&self) -> Option<i64> {
        match self {
            Val::Num { num, .. } => Some(*num),
            _ => None,
        }
    }

    /// Byte payload if this is a `Bytes`.
    pub fn as_bytes(&self) -> Option<&[u8]> {
        match self {
            Val::Bytes { data, .. } => Some(data),
            _ => None,
        }
    }

    /// CAS version, if the value carries one.
    pub fn version(&self) -> Option<i64> {
        match self {
            Val::Num { ver, .. } | Val::Bytes { ver, .. } => Some(*ver),
            _ => None,
        }
    }

    /// True for ∅.
    pub fn is_empty(&self) -> bool {
        matches!(self, Val::Empty)
    }

    /// True for a tombstone.
    pub fn is_tombstone(&self) -> bool {
        matches!(self, Val::Tombstone)
    }

    /// Packs the value into the `[ver, num]` i64 pair used by the L1
    /// kernel. `Empty` packs as `[-1, 0]`, `Tombstone` as `[-2, 0]`;
    /// `Bytes` is not packable (returns `None`).
    pub fn pack(&self) -> Option<[i64; 2]> {
        match self {
            Val::Empty => Some([-1, 0]),
            Val::Tombstone => Some([-2, 0]),
            Val::Num { ver, num } => Some([*ver, *num]),
            Val::Bytes { .. } => None,
        }
    }

    /// Inverse of [`Val::pack`].
    pub fn unpack(packed: [i64; 2]) -> Val {
        match packed[0] {
            -1 => Val::Empty,
            -2 => Val::Tombstone,
            ver => Val::Num { ver, num: packed[1] },
        }
    }
}

impl Codec for Val {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Val::Empty => out.push(0),
            Val::Tombstone => out.push(1),
            Val::Num { ver, num } => {
                out.push(2);
                ver.encode(out);
                num.encode(out);
            }
            Val::Bytes { ver, data } => {
                out.push(3);
                ver.encode(out);
                data.encode(out);
            }
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        match u8::decode(input)? {
            0 => Ok(Val::Empty),
            1 => Ok(Val::Tombstone),
            2 => Ok(Val::Num { ver: i64::decode(input)?, num: i64::decode(input)? }),
            3 => Ok(Val::Bytes { ver: i64::decode(input)?, data: Vec::<u8>::decode(input)? }),
            _ => Err(CodecError::Invalid("Val tag")),
        }
    }
}

impl std::fmt::Display for Val {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Val::Empty => write!(f, "∅"),
            Val::Tombstone => write!(f, "⊥"),
            Val::Num { ver, num } => write!(f, "({ver}, {num})"),
            Val::Bytes { ver, data } => write!(f, "({ver}, {} bytes)", data.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_roundtrip() {
        for v in [
            Val::Empty,
            Val::Tombstone,
            Val::Num { ver: 0, num: 0 },
            Val::Num { ver: 42, num: -7 },
            Val::Num { ver: i64::MAX - 2, num: i64::MIN },
        ] {
            assert_eq!(Val::unpack(v.pack().unwrap()), v);
        }
    }

    #[test]
    fn bytes_not_packable() {
        assert!(Val::Bytes { ver: 1, data: vec![1] }.pack().is_none());
    }

    #[test]
    fn codec_roundtrip() {
        for v in [
            Val::Empty,
            Val::Tombstone,
            Val::Num { ver: -1, num: i64::MIN },
            Val::Bytes { ver: 3, data: vec![1, 2, 3] },
        ] {
            assert_eq!(Val::from_bytes(&v.to_bytes()).unwrap(), v);
        }
    }

    #[test]
    fn accessors() {
        let v = Val::Num { ver: 3, num: 9 };
        assert_eq!(v.as_num(), Some(9));
        assert_eq!(v.version(), Some(3));
        assert!(!v.is_empty());
        assert!(Val::Empty.is_empty());
        assert!(Val::Tombstone.is_tombstone());
        assert_eq!(Val::Empty.version(), None);
    }
}
