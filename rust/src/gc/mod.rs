//! Deletion garbage collection (§3.1).
//!
//! Deleting a CASPaxos register is a two-act story. Act one is cheap: a
//! client writes a *tombstone* with the regular F+1 quorum
//! ([`crate::kv::KvStore::delete`]). Act two — actually reclaiming the
//! space — must not let a delayed message or a stale proposer cache
//! resurrect the value (the *lost delete* anomaly) nor let a tombstone
//! with a high ballot shadow a genuinely newer value (the *lost update*
//! anomaly). The paper's multi-step process, implemented here:
//!
//! 1. tombstone written at F+1 (already done before `collect` is called);
//! 2. (a) replicate the tombstone to **all** nodes by running the
//!        identity transform with the max (2F+1) accept quorum;
//!    (b) for every proposer: invalidate its cache for the key,
//!        fast-forward its counter past the tombstone's ballot, and
//!        increment its age;
//!    (c) tell every acceptor to reject messages from proposers younger
//!        than the ages recorded in (b);
//!    (d) erase the register from every acceptor that still holds the
//!        step-2a tombstone.
//!
//! Every step is idempotent, so a failed run can simply be retried
//! (`collect` returns an error and the queue holds the key).
//!
//! Lock-striped acceptors (`acceptor::StripedAcceptor`) are
//! transparent to this process: step 2c's `SetMinAge` broadcasts to
//! every stripe inside the acceptor (the fence must hold wherever a
//! fenced proposer's keys hash), and step 2d's `Erase` routes to the
//! key's owning stripe — collect walks all stripes without knowing
//! they exist.
//!
//! Checkpoints (`acceptor::FileStorage` checkpoint files, see the
//! storage module docs) are equally transparent, because every
//! compaction path goes through the checkpoint machinery: a register
//! erased in step 2d before a checkpoint is simply absent from the
//! checkpointed live set (the checkpoint is written from the in-memory
//! fold, which no longer holds it), and an `Erase` appended after a
//! checkpoint replays on top of the checkpoint-loaded state at restart
//! and removes the slot again. The min-age fences from step 2c are
//! part of the checkpointed state too, so a fenced proposer stays
//! fenced across checkpoint + crash + replay. There is no rewrite-style
//! compaction that could drop an `Erase` record while an older
//! checkpoint still holds the slot — that would resurrect deleted
//! registers, the exact §3.1 anomaly this module exists to prevent.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::change::ChangeFn;
use crate::error::{CasError, CasResult};
use crate::msg::{Key, Request, Response};
use crate::proposer::{Proposer, ProposerOpts};
use crate::quorum::{ClusterConfig, QuorumSpec};
use crate::transport::Transport;

/// Admin handle to one proposer — local (an [`Arc<Proposer>`]) or remote
/// (a peer node's admin endpoint, see `server::RemoteProposer`). GC step
/// 2b must reach EVERY proposer in the system; a proposer the GC cannot
/// sync blocks collection (§2.3.4 explains the proposer-list handshake
/// that keeps this sound when proposers come and go).
pub trait ProposerAdmin: Send + Sync {
    /// The proposer's id (admin registry key; used to deregister).
    fn id(&self) -> u64;
    /// Runs GC step 2b on the proposer: invalidate the key's cache
    /// entry, fast-forward the ballot counter past `min_counter`, bump
    /// the age. Returns `(proposer id, new age)` — the id may differ
    /// from [`ProposerAdmin::id`] for aggregate handles (a sharded peer
    /// node syncs ALL its shard proposers and reports the one that owns
    /// `key`, see `server::RemoteProposer`).
    fn gc_sync(&self, key: &Key, min_counter: u64) -> CasResult<(u64, u64)>;
}

impl ProposerAdmin for Arc<Proposer> {
    fn id(&self) -> u64 {
        Proposer::id(self)
    }
    fn gc_sync(&self, key: &Key, min_counter: u64) -> CasResult<(u64, u64)> {
        Ok((Proposer::id(self), Proposer::gc_sync(self, key, min_counter)))
    }
}

/// Outcome of a collection attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GcOutcome {
    /// The register was erased from every acceptor.
    Collected,
    /// A concurrent write revived the key; nothing was deleted.
    Superseded,
}

/// The background deletion GC.
///
/// Holds handles to every proposer (in a multi-process deployment these
/// would be admin RPC endpoints; the logic is identical) and the
/// transport to reach acceptors.
pub struct GcProcess {
    transport: Arc<dyn Transport>,
    proposers: Mutex<Vec<Box<dyn ProposerAdmin>>>,
    queue: Mutex<VecDeque<Key>>,
    /// Long-lived GC proposer: its age must advance together with the
    /// fences it installs, otherwise it would fence itself out after the
    /// first collection.
    gc_proposer: Mutex<Option<Arc<Proposer>>>,
    /// Dedicated GC proposer id (stays clear of client proposers).
    gc_proposer_id: u64,
}

impl GcProcess {
    /// Creates a GC over the given local proposer handles.
    /// `gc_proposer_id` defaults to 999 999; multi-node deployments MUST
    /// give each node's GC a distinct id via [`GcProcess::with_id`].
    pub fn new(transport: Arc<dyn Transport>, proposers: Vec<Arc<Proposer>>) -> Self {
        Self::with_id(transport, proposers, 999_999)
    }

    /// Creates a GC with an explicit GC-proposer id.
    pub fn with_id(
        transport: Arc<dyn Transport>,
        proposers: Vec<Arc<Proposer>>,
        gc_proposer_id: u64,
    ) -> Self {
        let proposers: Vec<Box<dyn ProposerAdmin>> =
            proposers.into_iter().map(|p| Box::new(p) as Box<dyn ProposerAdmin>).collect();
        GcProcess {
            transport,
            proposers: Mutex::new(proposers),
            queue: Mutex::new(VecDeque::new()),
            gc_proposer: Mutex::new(None),
            gc_proposer_id,
        }
    }

    /// Registers a proposer (see §2.3.4 on adding proposers: the GC's
    /// proposer list must be updated *before* the proposer goes live).
    pub fn add_proposer(&self, p: Arc<Proposer>) {
        self.proposers.lock().unwrap().push(Box::new(p));
    }

    /// Registers a remote proposer admin handle (a peer node).
    pub fn add_admin(&self, p: Box<dyn ProposerAdmin>) {
        self.proposers.lock().unwrap().push(p);
    }

    /// Removes a proposer from the GC's list (§2.3.4 removal, step 2).
    pub fn remove_proposer(&self, id: u64) {
        self.proposers.lock().unwrap().retain(|p| p.id() != id);
    }

    /// Schedules a key for collection (step 1 confirms to the client
    /// immediately; collection happens here, later).
    pub fn schedule(&self, key: impl Into<Key>) {
        self.queue.lock().unwrap().push_back(key.into());
    }

    /// Number of keys awaiting collection.
    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Processes the whole queue once; failed keys are re-queued.
    /// Returns (collected, superseded, failed).
    pub fn collect_all(&self, cfg: &ClusterConfig) -> (usize, usize, usize) {
        self.collect_all_with(|_| cfg.clone())
    }

    /// Like [`GcProcess::collect_all`], but the cluster config is looked
    /// up per key — a sharded deployment must collect each key against
    /// the acceptor group that hosts it, never the union (erasing /
    /// fencing on foreign shards would create registers there and break
    /// the share-nothing invariant). See `shard::ShardedKv::config_fn`.
    pub fn collect_all_with(
        &self,
        cfg_for: impl Fn(&Key) -> ClusterConfig,
    ) -> (usize, usize, usize) {
        let keys: Vec<Key> = {
            let mut q = self.queue.lock().unwrap();
            q.drain(..).collect()
        };
        let (mut ok, mut superseded, mut failed) = (0, 0, 0);
        for key in keys {
            match self.collect(&cfg_for(&key), &key) {
                Ok(GcOutcome::Collected) => ok += 1,
                Ok(GcOutcome::Superseded) => superseded += 1,
                Err(_) => {
                    failed += 1;
                    self.queue.lock().unwrap().push_back(key);
                }
            }
        }
        (ok, superseded, failed)
    }

    /// Runs steps 2a–2d for one key.
    pub fn collect(&self, cfg: &ClusterConfig, key: &Key) -> CasResult<GcOutcome> {
        // -- 2a: replicate the tombstone to ALL nodes (max accept quorum).
        let full_cfg = ClusterConfig {
            epoch: cfg.epoch,
            acceptors: cfg.acceptors.clone(),
            quorum: QuorumSpec::flexible(
                cfg.acceptors.len(),
                cfg.quorum.prepare,
                cfg.acceptors.len(),
            )?,
        };
        // The GC proposer is long-lived (see field doc); its config is
        // refreshed to the current full-quorum view on every collection.
        // Piggyback is off: the register is about to vanish.
        let gc_proposer = {
            let mut guard = self.gc_proposer.lock().unwrap();
            match guard.as_ref() {
                Some(p) => {
                    p.update_config(full_cfg)?;
                    Arc::clone(p)
                }
                None => {
                    let opts = ProposerOpts { piggyback: false, ..Default::default() };
                    let p = Arc::new(Proposer::with_opts(
                        self.gc_proposer_id,
                        full_cfg,
                        Arc::clone(&self.transport),
                        opts,
                    ));
                    *guard = Some(Arc::clone(&p));
                    p
                }
            }
        };
        let out = gc_proposer.change_detailed(key.clone(), ChangeFn::Read)?;
        if !out.state.is_tombstone() {
            // A concurrent write revived the key between the delete and
            // this collection: deletion is superseded, nothing to do.
            return Ok(GcOutcome::Superseded);
        }
        let tombstone_ballot = out.ballot;

        // -- 2b: sync every proposer (cache invalidation + counter
        //        fast-forward + age bump). Idempotent per proposer.
        let mut ages: Vec<(u64, u64)> = Vec::new();
        {
            let proposers = self.proposers.lock().unwrap();
            for p in proposers.iter() {
                // A proposer we cannot reach blocks the collection — the
                // whole point of step 2b is that NO proposer keeps a
                // stale cache or low counter past this point.
                ages.push(p.gc_sync(key, tombstone_ballot.counter)?);
            }
        }
        // The GC's own proposer is fenced too: a delayed 2a accept
        // message must not resurrect the value after 2d.
        let gc_age = Proposer::gc_sync(&gc_proposer, key, tombstone_ballot.counter);
        ages.push((self.gc_proposer_id, gc_age));

        // -- 2c: install min ages on every acceptor. Must reach ALL
        //        acceptors (reject-list is per-acceptor state).
        for &a in &cfg.acceptors {
            for &(proposer_id, min_age) in &ages {
                match self.transport.send(a, &Request::SetMinAge { proposer_id, min_age }) {
                    Ok(Response::Ok) => {}
                    Ok(r) => return Err(CasError::Transport(format!("SetMinAge on {a}: {r:?}"))),
                    Err(e) => return Err(e),
                }
            }
        }

        // -- 2d: erase the register where the tombstone still sits.
        for &a in &cfg.acceptors {
            match self.transport.send(a, &Request::Erase { key: key.clone(), tombstone_ballot }) {
                Ok(Response::Ok) => {}
                Ok(r) => return Err(CasError::Transport(format!("Erase on {a}: {r:?}"))),
                Err(e) => return Err(e),
            }
        }
        Ok(GcOutcome::Collected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::mem::MemTransport;

    struct World {
        transport: Arc<MemTransport>,
        cfg: ClusterConfig,
        p: Arc<Proposer>,
        gc: GcProcess,
    }

    fn world() -> World {
        let transport = Arc::new(MemTransport::new(3));
        let cfg = ClusterConfig::majority(1, transport.acceptor_ids());
        let p = Arc::new(Proposer::new(1, cfg.clone(), transport.clone()));
        let gc = GcProcess::new(transport.clone(), vec![p.clone()]);
        World { transport, cfg, p, gc }
    }

    fn register_count(w: &World, acceptor: u64) -> usize {
        w.transport.with_acceptor(acceptor, |a| a.register_count()).unwrap()
    }

    #[test]
    fn collect_erases_everywhere() {
        let w = world();
        w.p.set("k", 42).unwrap();
        w.p.delete("k").unwrap();
        w.gc.schedule("k");
        let (ok, sup, fail) = w.gc.collect_all(&w.cfg);
        assert_eq!((ok, sup, fail), (1, 0, 0));
        for a in 1..=3 {
            assert_eq!(register_count(&w, a), 0, "acceptor {a} still holds the register");
        }
    }

    #[test]
    fn concurrent_revival_supersedes_gc() {
        let w = world();
        w.p.set("k", 1).unwrap();
        w.p.delete("k").unwrap();
        // Revive before the GC runs.
        w.p.set("k", 2).unwrap();
        assert_eq!(w.gc.collect(&w.cfg, &"k".to_string()).unwrap(), GcOutcome::Superseded);
        assert_eq!(w.p.get("k").unwrap().as_num(), Some(2), "value survives");
    }

    #[test]
    fn collect_requires_all_acceptors() {
        let w = world();
        w.p.set("k", 1).unwrap();
        w.p.delete("k").unwrap();
        w.transport.set_down(3, true);
        w.gc.schedule("k");
        let (ok, _sup, fail) = w.gc.collect_all(&w.cfg);
        assert_eq!((ok, fail), (0, 1), "GC must not complete with a node down");
        assert_eq!(w.gc.pending(), 1, "rescheduled");
        // Node comes back; retry succeeds.
        w.transport.set_down(3, false);
        let (ok, _, fail) = w.gc.collect_all(&w.cfg);
        assert_eq!((ok, fail), (1, 0));
    }

    #[test]
    fn stale_proposer_is_fenced_after_gc() {
        let w = world();
        // A second proposer that the GC does NOT know about models a
        // proposer that missed step 2b (e.g. it was partitioned away).
        let stale = Proposer::new(2, w.cfg.clone(), w.transport.clone());
        stale.set("k", 42).unwrap(); // builds a 1-RTT cache entry for k
        w.p.delete("k").unwrap();
        w.gc.collect(&w.cfg, &"k".to_string()).unwrap();
        // The acceptors only fence proposers the GC knew (id 1 and the GC
        // itself): proposer 2 was never synced. Simulate the paper's
        // requirement that the GC knows ALL proposers by adding it and
        // re-collecting a second key.
        w.gc.add_proposer(Arc::new(stale));
        w.p.set("k2", 1).unwrap();
        w.p.delete("k2").unwrap();
        w.gc.collect(&w.cfg, &"k2".to_string()).unwrap();
        // Now proposer 2's age on acceptors is 1; a proposer stuck at age
        // 0 gets StaleAge. (gc_sync bumped the real handle, so emulate an
        // old incarnation by a fresh proposer with the same id, age 0.)
        let old_incarnation = Proposer::new(2, w.cfg.clone(), w.transport.clone());
        match old_incarnation.set("k2", 99) {
            Err(CasError::StaleAge { required, got }) => {
                assert!(required >= 1);
                assert_eq!(got, 0);
            }
            r => panic!("expected StaleAge fence, got {r:?}"),
        }
    }

    #[test]
    fn lost_delete_anomaly_is_prevented() {
        // The §3.1 anomaly: a proposer with a cached value (1-RTT path)
        // could revive a deleted register without a causal link. After
        // GC, the cached proposer must be fenced or fast-forwarded.
        let w = world();
        w.p.set("k", 42).unwrap(); // 1-RTT cache now holds k
        let (hits_before, _) = w.p.cache_stats();
        w.p.delete("k").unwrap();
        w.gc.collect(&w.cfg, &"k".to_string()).unwrap();
        // The GC synced proposer 1 (cache invalidated, age bumped), so
        // this write is a fresh full round, not a cached accept.
        w.p.set("k", 7).unwrap();
        assert_eq!(w.p.get("k").unwrap().as_num(), Some(7));
        let _ = hits_before;
        // And the new value's ballot is beyond the tombstone's (counter
        // fast-forward), so no reader can prefer a stale tombstone.
        for a in 1..=3 {
            let slot = w
                .transport
                .with_acceptor(a, |acc| acc.storage_value("k"))
                .unwrap();
            assert_eq!(slot, Some(7));
        }
    }

    #[test]
    fn sharded_collect_routes_to_owning_group() {
        use crate::shard::ShardPlan;
        let transport = Arc::new(MemTransport::new(6));
        let plan = ShardPlan::partition(transport.acceptor_ids(), 2, None).unwrap();
        let kv = crate::kv::KvStore::new_sharded(plan, transport.clone(), 1).unwrap();
        let gc = GcProcess::new(transport.clone(), kv.proposers().to_vec());
        for i in 0..10 {
            kv.set(&format!("k{i}"), i).unwrap();
        }
        for i in 0..10 {
            kv.delete(&format!("k{i}")).unwrap();
            gc.schedule(format!("k{i}"));
        }
        let (ok, sup, failed) = gc.collect_all_with(kv.sharded().config_fn());
        assert_eq!((ok, sup, failed), (10, 0, 0));
        // Everything erased, and no register ever leaked onto a foreign
        // shard's acceptors.
        for a in 1..=6 {
            assert_eq!(transport.register_count(a), Some(0), "acceptor {a} not empty");
        }
    }

    #[test]
    fn collect_walks_striped_acceptors() {
        // 4-stripe nodes: erase must reclaim every key on its owning
        // stripe, and the 2c min-age fence must hold on EVERY stripe.
        let transport = Arc::new(MemTransport::new_striped(3, 4));
        let cfg = ClusterConfig::majority(1, transport.acceptor_ids());
        let p = Arc::new(Proposer::new(1, cfg.clone(), transport.clone()));
        let gc = GcProcess::new(transport.clone(), vec![p.clone()]);
        for i in 0..8 {
            p.set(format!("k{i}"), i).unwrap();
        }
        for i in 0..8 {
            p.delete(format!("k{i}")).unwrap();
            gc.schedule(format!("k{i}"));
        }
        let (ok, sup, failed) = gc.collect_all(&cfg);
        assert_eq!((ok, sup, failed), (8, 0, 0));
        for a in 1..=3 {
            assert_eq!(transport.register_count(a), Some(0), "acceptor {a} not reclaimed");
        }
        // An old incarnation (age 0) is fenced no matter which stripe
        // its key hashes to.
        let old = Proposer::new(1, cfg, transport.clone());
        for i in 0..8 {
            assert!(
                matches!(old.set(format!("k{i}"), 1), Err(CasError::StaleAge { .. })),
                "k{i}'s stripe missed the min-age fence"
            );
        }
    }

    #[test]
    fn collect_is_idempotent() {
        let w = world();
        w.p.set("k", 1).unwrap();
        w.p.delete("k").unwrap();
        assert_eq!(w.gc.collect(&w.cfg, &"k".to_string()).unwrap(), GcOutcome::Collected);
        // Second run: the register is gone; identity on an erased key
        // reads Empty -> superseded (nothing to collect).
        assert_eq!(w.gc.collect(&w.cfg, &"k".to_string()).unwrap(), GcOutcome::Superseded);
    }
}
