//! In-tree test support: temp directories and a seeded property-test
//! harness (the offline dependency set has no proptest/tempfile; the
//! substitution is documented in DESIGN.md).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::rng::Rng;

static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Creates a unique directory under the system temp dir.
    pub fn new(prefix: &str) -> std::io::Result<Self> {
        let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!("caspaxos-{prefix}-{pid}-{nanos}-{seq}"));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A file path inside the directory.
    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// The hook installed by the panic hook (see below).
#[allow(deprecated)] // PanicInfo: the pre-1.81 name keeps old toolchains compiling
type PanicHook = Box<dyn Fn(&std::panic::PanicInfo<'_>) + Sync + Send + 'static>;

/// Refcounted panic-hook silencer shared by every concurrently running
/// `forall_seeds` (libtest runs tests in parallel and the hook is
/// process-global): the first harness in saves the current hook and
/// installs a no-op, the last one out restores it.
static SILENCED: Mutex<(usize, Option<PanicHook>)> = Mutex::new((0, None));

struct SilenceGuard;

impl SilenceGuard {
    fn new() -> Self {
        let mut g = SILENCED.lock().unwrap_or_else(|e| e.into_inner());
        if g.0 == 0 {
            g.1 = Some(std::panic::take_hook());
            std::panic::set_hook(Box::new(|_| {}));
        }
        g.0 += 1;
        SilenceGuard
    }
}

impl Drop for SilenceGuard {
    fn drop(&mut self) {
        let mut g = SILENCED.lock().unwrap_or_else(|e| e.into_inner());
        g.0 -= 1;
        if g.0 == 0 {
            if let Some(prev) = g.1.take() {
                std::panic::set_hook(prev);
            }
        }
    }
}

/// Opens (or reopens) a file-backed lock-striped acceptor in `dir` —
/// the shared constructor of the striped crash-recovery pins
/// (`tests/durability.rs`) and any chaos world that wants durable
/// striped nodes. One shared group-commit WAL at
/// `dir/acceptor-{id}.log`, `stripes` slot maps rebuilt by
/// stripe-filtered replay. fsync is off (tmpfs CI keeps the tests
/// fast); CRC framing, replay and the torn-tail rules are unaffected.
pub fn striped_file_acceptor(
    dir: &TempDir,
    id: u64,
    stripes: usize,
) -> crate::acceptor::StripedAcceptor<crate::acceptor::FileStorage> {
    let mut stores = crate::acceptor::FileStorage::open_striped(
        dir.file(&format!("acceptor-{id}.log")),
        crate::acceptor::GroupCommitOpts::default(),
        stripes,
    )
    .expect("open striped log");
    for s in &mut stores {
        s.fsync = false;
    }
    crate::acceptor::StripedAcceptor::from_storages(id, stores)
}

/// Disk-backed twin of [`striped_file_acceptor`]: same WAL path and
/// checkpoint format on the same `dir`, but slots live in per-stripe
/// segment files behind a `cache_slots`-bounded cache — the shared
/// constructor for running the durability/crash suites against the
/// [`crate::acceptor::Backend::Disk`] backend. fsync is off, as above.
pub fn striped_disk_acceptor(
    dir: &TempDir,
    id: u64,
    stripes: usize,
    cache_slots: usize,
) -> crate::acceptor::StripedAcceptor<crate::acceptor::DiskStorage> {
    let mut stores = crate::acceptor::DiskStorage::open_striped(
        dir.file(&format!("acceptor-{id}.log")),
        crate::acceptor::GroupCommitOpts::default(),
        stripes,
        cache_slots,
    )
    .expect("open striped disk backend");
    for s in &mut stores {
        s.fsync = false;
    }
    crate::acceptor::StripedAcceptor::from_storages(id, stores)
}

/// A key routed to stripe `want` of `stripes` by
/// [`crate::acceptor::stripe_of`] (probes the shared hash; `salt`
/// namespaces the keys so callers never share a register). Shared by
/// the striped storage tests and `benches/write_path.rs`, so a routing
/// change can't silently strand one of them.
pub fn key_on_stripe(want: usize, stripes: usize, salt: u64) -> String {
    (0..)
        .map(|i| format!("s{salt}-{i}"))
        .find(|k| crate::acceptor::stripe_of(k, stripes) == want)
        .expect("crc32 reaches every stripe")
}

/// Seed count for one chaos campaign: `base`, scaled by the
/// `CHAOS_SEED_MULT` env var (the nightly `chaos-extended` and
/// `tcp-chaos` CI legs run with 4×; failing case seeds print via
/// [`forall_seeds`] and are uploaded as artifacts for replay). Shared
/// by every campaign so scaling rules can't drift between suites.
pub fn chaos_seed_count(base: u64) -> u64 {
    let mult = std::env::var("CHAOS_SEED_MULT")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(1);
    base * mult.max(1)
}

/// Minimal property-test harness: runs `body` for `cases` deterministic
/// seeds derived from `seed`. On failure the panic message names the
/// failing case seed so it can be replayed exactly.
///
/// The default panic hook is silenced while cases run and restored
/// afterwards (guard-dropped even on failure): the harness *expects*
/// assertion panics from failing cases and re-raises them with the
/// replay seed attached, so the hook's own backtrace spam for the
/// caught panic is pure noise.
pub fn forall_seeds(seed: u64, cases: u64, mut body: impl FnMut(&mut Rng)) {
    let guard = SilenceGuard::new();
    let mut failure: Option<(u64, u64, String)> = None;
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case.wrapping_mul(0xD1B54A32D192ED03));
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            failure = Some((case, case_seed, msg));
            break;
        }
    }
    // Restore the hook BEFORE re-raising, so the replay-seed message is
    // reported through the normal (un-silenced) panic path.
    drop(guard);
    if let Some((case, case_seed, msg)) = failure {
        // A concurrently running harness may still be holding the hook
        // silenced (the refcount only restores on the LAST exit); print
        // the replay line directly so it always reaches the captured
        // test output regardless.
        eprintln!("property failed (case {case}, replay seed {case_seed:#x}): {msg}");
        panic!("property failed (case {case}, replay seed {case_seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_cleans() {
        let kept;
        {
            let d = TempDir::new("t").unwrap();
            kept = d.path().to_path_buf();
            std::fs::write(d.file("x"), b"hi").unwrap();
            assert!(kept.exists());
        }
        assert!(!kept.exists(), "dropped TempDir removes its tree");
    }

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall_seeds(1, 10, |_| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn forall_reports_failing_seed() {
        forall_seeds(2, 5, |rng| {
            let v = rng.gen_range(1000);
            assert!(v > 1000, "draw {v} can never exceed the bound");
        });
    }

    #[test]
    fn forall_failure_restores_hook_and_reports() {
        let err = std::panic::catch_unwind(|| {
            forall_seeds(9, 3, |_| panic!("boom"));
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
        // The harness (and the silencer refcount) remain usable after a
        // failure escaped through the guard.
        let mut n = 0;
        forall_seeds(1, 4, |_| n += 1);
        assert_eq!(n, 4);
    }
}
