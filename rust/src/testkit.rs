//! In-tree test support: temp directories and a seeded property-test
//! harness (the offline dependency set has no proptest/tempfile; the
//! substitution is documented in DESIGN.md).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::rng::Rng;

static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Creates a unique directory under the system temp dir.
    pub fn new(prefix: &str) -> std::io::Result<Self> {
        let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!("caspaxos-{prefix}-{pid}-{nanos}-{seq}"));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A file path inside the directory.
    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Minimal property-test harness: runs `body` for `cases` deterministic
/// seeds derived from `seed`. On failure the panic message names the
/// failing case seed so it can be replayed exactly.
pub fn forall_seeds(seed: u64, cases: u64, mut body: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case.wrapping_mul(0xD1B54A32D192ED03));
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed (case {case}, replay seed {case_seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_cleans() {
        let kept;
        {
            let d = TempDir::new("t").unwrap();
            kept = d.path().to_path_buf();
            std::fs::write(d.file("x"), b"hi").unwrap();
            assert!(kept.exists());
        }
        assert!(!kept.exists(), "dropped TempDir removes its tree");
    }

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall_seeds(1, 10, |_| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn forall_reports_failing_seed() {
        forall_seeds(2, 5, |rng| {
            let v = rng.gen_range(1000);
            assert!(v > 1000, "draw {v} can never exceed the bound");
        });
    }
}
