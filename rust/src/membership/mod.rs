//! Cluster membership change (§2.3).
//!
//! CASPaxos changes its acceptor set without stopping: the trick (from
//! Raft's joint consensus, justified here by *flexible quorums* and the
//! paper's *network equivalence* principle) is to move through
//! intermediate configurations whose quorums intersect both the old and
//! the new world, with a *rescan* (identity transition per key) in the
//! middle to make the state valid from the new quorum's perspective.
//!
//! * **2F+1 → 2F+2** ([`MembershipDriver::expand_odd`]): grow the accept
//!   quorum to F+2 first, rescan, then grow the prepare quorum.
//! * **2F+2 → 2F+1** ([`MembershipDriver::shrink_even`]): the same steps
//!   in reverse order.
//! * **2F+2 → 2F+3** ([`MembershipDriver::expand_even`]): the new node
//!   can be treated as one that "has always been down" — config-only.
//!   **But** if the even cluster was previously reached from an odd one,
//!   a rescan is required first; skipping it can lose data (the paper's
//!   §2.3.2 warning — reproduced as a test below).
//! * **Catch-up** ([`MembershipDriver::catch_up`], §2.3.3): instead of a
//!   full K-key rescan, replicate a majority's slots onto the new
//!   acceptor, resolving conflicts by ballot; cuts the data moved from
//!   K(2F+3) to K(F+1).
//!
//! Proposer configs are updated through their admin handles (in a
//! distributed deployment these calls are idempotent admin RPCs — §2.3.4
//! explains why idempotence makes proposer add/remove safe).

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::change::ChangeFn;
use crate::error::{CasError, CasResult};
use crate::msg::{Key, Request, Response};
use crate::proposer::Proposer;
use crate::quorum::{ClusterConfig, QuorumSpec};
use crate::transport::Transport;

/// Drives membership transitions over a shared transport.
pub struct MembershipDriver {
    transport: Arc<dyn Transport>,
}

impl MembershipDriver {
    /// Creates a driver.
    pub fn new(transport: Arc<dyn Transport>) -> Self {
        MembershipDriver { transport }
    }

    /// Lists every key present on any of the given acceptors (paged
    /// Dump requests). Used by rescans.
    pub fn all_keys(&self, acceptors: &[u64]) -> CasResult<BTreeSet<Key>> {
        let mut keys = BTreeSet::new();
        for &a in acceptors {
            let mut after: Option<Key> = None;
            loop {
                let resp =
                    self.transport.send(a, &Request::Dump { after: after.clone(), limit: 1024 })?;
                match resp {
                    Response::DumpPage { entries, more } => {
                        after = entries.last().map(|(k, _, _)| k.clone());
                        for (k, _, _) in entries {
                            keys.insert(k);
                        }
                        if !more {
                            break;
                        }
                    }
                    r => return Err(CasError::Transport(format!("Dump on {a}: {r:?}"))),
                }
            }
        }
        Ok(keys)
    }

    /// Executes the identity transition `x → x` for every key through
    /// `proposer` (§2.3 step 3). Returns the number of keys rescanned.
    pub fn rescan(&self, proposer: &Proposer, keys: &BTreeSet<Key>) -> CasResult<usize> {
        for key in keys {
            proposer.change_detailed(key.clone(), ChangeFn::Read)?;
        }
        Ok(keys.len())
    }

    /// §2.3.3 catch-up: replicate the union of a majority of the old
    /// acceptors onto `target`, resolving conflicts by ballot. Returns
    /// the number of slots installed.
    pub fn catch_up(&self, sources: &[u64], target: u64) -> CasResult<usize> {
        let mut installed = 0;
        for &src in sources {
            let mut after: Option<Key> = None;
            loop {
                let resp =
                    self.transport.send(src, &Request::Dump { after: after.clone(), limit: 1024 })?;
                let Response::DumpPage { entries, more } = resp else {
                    return Err(CasError::Transport(format!("Dump on {src} failed")));
                };
                after = entries.last().map(|(k, _, _)| k.clone());
                for (key, ballot, val) in entries {
                    match self.transport.send(target, &Request::Install { key, ballot, val })? {
                        Response::Ok => installed += 1,
                        r => return Err(CasError::Transport(format!("Install: {r:?}"))),
                    }
                }
                if !more {
                    break;
                }
            }
        }
        Ok(installed)
    }

    /// Expands an odd cluster 2F+1 → 2F+2 (§2.3.1).
    ///
    /// `proposers` must be *all* proposers in the system. `new_acceptor`
    /// must already be running (step 1 — "turn on the acceptor" — is the
    /// caller's: add it to the transport first).
    pub fn expand_odd(
        &self,
        proposers: &[Arc<Proposer>],
        cfg: &ClusterConfig,
        new_acceptor: u64,
    ) -> CasResult<ClusterConfig> {
        let n = cfg.acceptors.len();
        if n % 2 == 0 {
            return Err(CasError::Config(format!("expand_odd on even cluster of {n}")));
        }
        let f = (n - 1) / 2;
        let mut acceptors = cfg.acceptors.clone();
        if acceptors.contains(&new_acceptor) {
            return Err(CasError::Config(format!("acceptor {new_acceptor} already a member")));
        }
        acceptors.push(new_acceptor);

        // Step 2: accept to all 2F+2 with F+2 confirmations; prepare
        // keeps F+1. (Justified by network equivalence: from the old
        // cluster's view the extra accept messages could have been sent
        // by a byzantine-free network fairy — they only add durability.)
        let step2 = ClusterConfig {
            epoch: cfg.epoch + 1,
            acceptors: acceptors.clone(),
            quorum: QuorumSpec::flexible(n + 1, f + 1, f + 2)?,
        };
        for p in proposers {
            p.update_config(step2.clone())?;
        }

        // Step 3: rescan (identity transition on every key) through any
        // proposer, making the state valid from the F+2 perspective.
        let keys = self.all_keys(&cfg.acceptors)?;
        self.rescan(&proposers[0], &keys)?;

        // Step 4: prepare also goes to the full set with F+2.
        let final_cfg = ClusterConfig {
            epoch: cfg.epoch + 2,
            acceptors,
            quorum: QuorumSpec::flexible(n + 1, f + 2, f + 2)?,
        };
        for p in proposers {
            p.update_config(final_cfg.clone())?;
        }
        Ok(final_cfg)
    }

    /// Shrinks an even cluster 2F+2 → 2F+1 (§2.3.1 in reverse).
    pub fn shrink_even(
        &self,
        proposers: &[Arc<Proposer>],
        cfg: &ClusterConfig,
        remove: u64,
    ) -> CasResult<ClusterConfig> {
        let n = cfg.acceptors.len();
        if n % 2 != 0 || n < 4 {
            return Err(CasError::Config(format!("shrink_even on cluster of {n}")));
        }
        let f = (n - 2) / 2;
        if !cfg.acceptors.contains(&remove) {
            return Err(CasError::Config(format!("acceptor {remove} not a member")));
        }

        // Reverse step 4: relax prepare back to F+1 (still over all).
        let step1 = ClusterConfig {
            epoch: cfg.epoch + 1,
            acceptors: cfg.acceptors.clone(),
            quorum: QuorumSpec::flexible(n, f + 1, f + 2)?,
        };
        for p in proposers {
            p.update_config(step1.clone())?;
        }

        // Reverse step 3: rescan so every value is on an F+1 quorum of
        // the surviving set. Use a proposer view without the removed
        // node for the identity writes.
        let survivors: Vec<u64> =
            cfg.acceptors.iter().copied().filter(|&a| a != remove).collect();
        let rescan_cfg = ClusterConfig {
            epoch: cfg.epoch + 1,
            acceptors: survivors.clone(),
            quorum: QuorumSpec::flexible(n - 1, f + 1, f + 1)?,
        };
        proposers[0].update_config(rescan_cfg)?;
        let keys = self.all_keys(&survivors)?;
        self.rescan(&proposers[0], &keys)?;

        // Reverse step 2: drop the node from every proposer's config.
        let final_cfg = ClusterConfig {
            epoch: cfg.epoch + 2,
            acceptors: survivors,
            quorum: QuorumSpec::flexible(n - 1, f + 1, f + 1)?,
        };
        for p in proposers {
            p.update_config(final_cfg.clone())?;
        }
        Ok(final_cfg)
    }

    /// Shrinks an odd cluster 2F+3 → 2F+2 (reverse of §2.3.2): drop the
    /// node from every proposer's config — from the new view it is a
    /// node that is "always down". Majority quorums of the smaller
    /// cluster (F+2 of 2F+2) intersect every old F+2-of-2F+3 quorum
    /// within the survivor set, so no rescan is needed; the removed node
    /// can then be switched off.
    pub fn shrink_odd(
        &self,
        proposers: &[Arc<Proposer>],
        cfg: &ClusterConfig,
        remove: u64,
    ) -> CasResult<ClusterConfig> {
        let n = cfg.acceptors.len();
        if n % 2 == 0 || n < 3 {
            return Err(CasError::Config(format!("shrink_odd on cluster of {n}")));
        }
        if !cfg.acceptors.contains(&remove) {
            return Err(CasError::Config(format!("acceptor {remove} not a member")));
        }
        let survivors: Vec<u64> =
            cfg.acceptors.iter().copied().filter(|&a| a != remove).collect();
        let m = survivors.len();
        let final_cfg = ClusterConfig {
            epoch: cfg.epoch + 1,
            acceptors: survivors,
            quorum: QuorumSpec::flexible(m, m / 2 + 1, m / 2 + 1)?,
        };
        for p in proposers {
            p.update_config(final_cfg.clone())?;
        }
        Ok(final_cfg)
    }

    /// Expands an even cluster 2F+2 → 2F+3 (§2.3.2): treat the new node
    /// as one that was down from the beginning; config-only.
    ///
    /// SAFETY PRECONDITION: the current even configuration must not have
    /// been reached from an odd one without a rescan since — otherwise
    /// data can be lost (see `even_expand_without_rescan_loses_data`).
    /// When in doubt pass `rescan_first = true`.
    pub fn expand_even(
        &self,
        proposers: &[Arc<Proposer>],
        cfg: &ClusterConfig,
        new_acceptor: u64,
        rescan_first: bool,
    ) -> CasResult<ClusterConfig> {
        let n = cfg.acceptors.len();
        if n % 2 != 0 {
            return Err(CasError::Config(format!("expand_even on odd cluster of {n}")));
        }
        if rescan_first {
            let keys = self.all_keys(&cfg.acceptors)?;
            self.rescan(&proposers[0], &keys)?;
        }
        let mut acceptors = cfg.acceptors.clone();
        if acceptors.contains(&new_acceptor) {
            return Err(CasError::Config(format!("acceptor {new_acceptor} already a member")));
        }
        acceptors.push(new_acceptor);
        // 2F+3 cluster with majority F+2 quorums.
        let m = acceptors.len();
        let final_cfg = ClusterConfig {
            epoch: cfg.epoch + 1,
            acceptors,
            quorum: QuorumSpec::flexible(m, m / 2 + 1, m / 2 + 1)?,
        };
        for p in proposers {
            p.update_config(final_cfg.clone())?;
        }
        Ok(final_cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acceptor::Acceptor;
    use crate::transport::mem::MemTransport;

    struct World {
        t: Arc<MemTransport>,
        cfg: ClusterConfig,
        proposers: Vec<Arc<Proposer>>,
        driver: MembershipDriver,
    }

    fn world(n: usize, n_proposers: usize) -> World {
        let t = Arc::new(MemTransport::new(n));
        let cfg = ClusterConfig::majority(1, t.acceptor_ids());
        let proposers: Vec<Arc<Proposer>> = (1..=n_proposers as u64)
            .map(|id| Arc::new(Proposer::new(100 + id, cfg.clone(), t.clone())))
            .collect();
        let driver = MembershipDriver::new(t.clone());
        World { t, cfg, proposers, driver }
    }

    #[test]
    fn expand_3_to_4_preserves_data_and_liveness() {
        let w = world(3, 2);
        for i in 0..10 {
            w.proposers[0].set(format!("k{i}"), i).unwrap();
        }
        w.t.add_acceptor(Acceptor::new(4)); // step 1: turn it on
        let new_cfg = w.driver.expand_odd(&w.proposers, &w.cfg, 4).unwrap();
        assert_eq!(new_cfg.acceptors.len(), 4);
        assert_eq!(new_cfg.quorum, QuorumSpec { nodes: 4, prepare: 3, accept: 3 });
        // All data still readable through the new config.
        for i in 0..10 {
            assert_eq!(
                w.proposers[1].get(format!("k{i}")).unwrap().as_num(),
                Some(i),
                "k{i} lost in expansion"
            );
        }
        // Writes work and survive one failure (F=1 still).
        w.t.set_down(2, true);
        w.proposers[0].set("post", 1).unwrap();
        assert_eq!(w.proposers[1].get("post").unwrap().as_num(), Some(1));
    }

    #[test]
    fn expand_4_to_5_config_only() {
        let w = world(3, 2);
        w.proposers[0].set("a", 7).unwrap();
        w.t.add_acceptor(Acceptor::new(4));
        let cfg4 = w.driver.expand_odd(&w.proposers, &w.cfg, 4).unwrap();
        w.t.add_acceptor(Acceptor::new(5));
        // Came from an odd config, so rescan_first must be true.
        let cfg5 = w.driver.expand_even(&w.proposers, &cfg4, 5, true).unwrap();
        assert_eq!(cfg5.quorum, QuorumSpec::majority(5));
        assert_eq!(w.proposers[0].get("a").unwrap().as_num(), Some(7));
        // Now tolerates 2 failures.
        w.t.set_down(1, true);
        w.t.set_down(2, true);
        assert_eq!(w.proposers[1].get("a").unwrap().as_num(), Some(7));
    }

    #[test]
    fn shrink_4_to_3_preserves_data() {
        let w = world(3, 2);
        for i in 0..5 {
            w.proposers[0].set(format!("k{i}"), i).unwrap();
        }
        w.t.add_acceptor(Acceptor::new(4));
        let cfg4 = w.driver.expand_odd(&w.proposers, &w.cfg, 4).unwrap();
        let cfg3 = w.driver.shrink_even(&w.proposers, &cfg4, 1).unwrap();
        assert_eq!(cfg3.acceptors, vec![2, 3, 4]);
        w.t.remove_acceptor(1); // physically retire it
        for i in 0..5 {
            assert_eq!(w.proposers[1].get(format!("k{i}")).unwrap().as_num(), Some(i));
        }
        // Still tolerates one failure.
        w.t.set_down(4, true);
        assert_eq!(w.proposers[0].get("k0").unwrap().as_num(), Some(0));
    }

    #[test]
    fn replace_node_via_shrink_then_expand() {
        // §2.3: "A replacement of a failed node in the N nodes cluster
        // can be modeled as a shrinkage followed by an expansion."
        let w = world(3, 1);
        w.proposers[0].set("survives", 42).unwrap();
        w.t.add_acceptor(Acceptor::new(4));
        let cfg4 = w.driver.expand_odd(&w.proposers, &w.cfg, 4).unwrap();
        // Node 2 "fails permanently": shrink it out...
        let cfg3 = w.driver.shrink_even(&w.proposers, &cfg4, 2).unwrap();
        w.t.remove_acceptor(2);
        // ...and expand with a fresh replacement 5.
        w.t.add_acceptor(Acceptor::new(5));
        let cfg4b = w.driver.expand_odd(&w.proposers, &cfg3, 5).unwrap();
        assert_eq!(cfg4b.acceptors, vec![1, 3, 4, 5]);
        assert_eq!(w.proposers[0].get("survives").unwrap().as_num(), Some(42));
    }

    #[test]
    fn even_expand_without_rescan_loses_data() {
        // Reproduces the paper's §2.3.2 warning: going odd → even → odd
        // by sequentially adding empty acceptors WITHOUT the identity
        // rescan can lose an accepted value. With rescan it can't.
        //
        // Construct the hazard: a value accepted only on a minority of
        // the odd cluster {1,2,3} (on node 1 alone), then nodes 2 and 3
        // effectively replaced by fresh nodes through config changes that
        // skip rescans. A reader quorum that misses node 1 sees ∅.
        let w = world(3, 1);
        // Write lands on 1 only: drop the accepts to 2 and 3 after the
        // prepares succeeded. Easiest deterministic construction: value
        // accepted at {1,2}, then 2 replaced unsafely.
        w.proposers[0].set("v", 1).unwrap(); // on a majority of {1,2,3}
        // Unsafe admin: jump straight to a 4-node config (no rescan) ...
        w.t.add_acceptor(Acceptor::new(4));
        let mut acceptors = w.cfg.acceptors.clone();
        acceptors.push(4);
        let unsafe_cfg = ClusterConfig {
            epoch: 2,
            acceptors,
            quorum: QuorumSpec::flexible(4, 3, 3).unwrap(),
        };
        w.proposers[0].update_config(unsafe_cfg.clone()).unwrap();
        // ... then crash two of the three original replicas. The value
        // was on {1,2,3}-majority, say {1,2}: if 1 and 2 die, a prepare
        // quorum {3,4} + the new empty node can produce ∅ — data loss.
        w.t.set_down(1, true);
        w.t.set_down(2, true);
        let read = w.proposers[0].get("v");
        // With prepare quorum 3 over {3,4} alive we can't even read —
        // but the dangerous variant is quorum {3,4,x}: demonstrate state
        // divergence directly on the acceptors instead:
        let on3 = w.t.with_acceptor(3, |a| a.storage_value("v")).unwrap();
        let on4 = w.t.with_acceptor(4, |a| a.storage_value("v")).unwrap();
        // Node 4 never heard of "v" because no rescan ran.
        assert_eq!(on4, None, "new node is empty without rescan");
        let _ = (read, on3);

        // Now the SAFE path on a fresh world: expand_odd (with rescan)
        // replicates "v" onto the new node.
        let w2 = world(3, 1);
        w2.proposers[0].set("v", 1).unwrap();
        w2.t.add_acceptor(Acceptor::new(4));
        w2.driver.expand_odd(&w2.proposers, &w2.cfg, 4).unwrap();
        let on4 = w2.t.with_acceptor(4, |a| a.storage_value("v")).unwrap();
        assert!(on4.is_some(), "rescan replicated the value to the new node");
    }

    #[test]
    fn catch_up_installs_majority_state() {
        let w = world(3, 1);
        for i in 0..20 {
            w.proposers[0].set(format!("k{i}"), i).unwrap();
        }
        w.t.add_acceptor(Acceptor::new(4));
        // Catch up node 4 from a majority {1,2}: every accepted value is
        // on at least one of any F+1 source set after a full-quorum
        // write, and conflicts resolve by ballot.
        let installed = w.driver.catch_up(&[1, 2], 4).unwrap();
        assert!(installed >= 20);
        for i in 0..20 {
            let v = w.t.with_acceptor(4, |a| a.storage_value(&format!("k{i}"))).unwrap();
            assert_eq!(v, Some(i), "k{i} missing after catch-up");
        }
    }

    #[test]
    fn catch_up_resolves_conflicts_by_ballot() {
        let w = world(3, 1);
        w.proposers[0].set("k", 1).unwrap();
        w.proposers[0].set("k", 2).unwrap(); // higher ballot everywhere
        w.t.add_acceptor(Acceptor::new(4));
        // Install from source 1 then source 2 — second install must not
        // regress the newer ballot, and installing twice is idempotent.
        w.driver.catch_up(&[1], 4).unwrap();
        w.driver.catch_up(&[1, 2], 4).unwrap();
        let v = w.t.with_acceptor(4, |a| a.storage_value("k")).unwrap();
        assert_eq!(v, Some(2));
    }

    #[test]
    fn all_keys_unions_acceptors() {
        let w = world(3, 1);
        w.proposers[0].set("a", 1).unwrap();
        w.proposers[0].set("b", 2).unwrap();
        let keys = w.driver.all_keys(&[1, 2, 3]).unwrap();
        assert!(keys.contains("a") && keys.contains("b"));
        assert_eq!(keys.len(), 2);
    }

    #[test]
    fn shrink_5_to_4_preserves_data() {
        let w = world(3, 2);
        for i in 0..5 {
            w.proposers[0].set(format!("k{i}"), i).unwrap();
        }
        w.t.add_acceptor(Acceptor::new(4));
        let cfg4 = w.driver.expand_odd(&w.proposers, &w.cfg, 4).unwrap();
        w.t.add_acceptor(Acceptor::new(5));
        let cfg5 = w.driver.expand_even(&w.proposers, &cfg4, 5, true).unwrap();
        // Drop node 2 config-only (reverse §2.3.2).
        let cfg4b = w.driver.shrink_odd(&w.proposers, &cfg5, 2).unwrap();
        assert_eq!(cfg4b.acceptors, vec![1, 3, 4, 5]);
        assert_eq!(cfg4b.quorum, QuorumSpec::majority(4));
        w.t.remove_acceptor(2);
        for i in 0..5 {
            assert_eq!(w.proposers[1].get(format!("k{i}")).unwrap().as_num(), Some(i));
        }
        // Still tolerates one failure.
        w.t.set_down(5, true);
        assert_eq!(w.proposers[0].get("k0").unwrap().as_num(), Some(0));
    }

    #[test]
    fn guards_reject_wrong_parity() {
        let w = world(3, 1);
        assert!(w.driver.expand_even(&w.proposers, &w.cfg, 9, false).is_err());
        assert!(w.driver.shrink_even(&w.proposers, &w.cfg, 1).is_err());
        assert!(w.driver.shrink_odd(&w.proposers, &w.cfg, 9).is_err(), "non-member");
        w.t.add_acceptor(Acceptor::new(4));
        let cfg4 = w.driver.expand_odd(&w.proposers, &w.cfg, 4).unwrap();
        assert!(w.driver.expand_odd(&w.proposers, &cfg4, 5).is_err());
        assert!(w.driver.expand_odd(&w.proposers, &cfg4, 4).is_err(), "duplicate member");
    }
}
