//! Lightweight metrics: atomic counters and latency histograms.
//!
//! Self-contained (no external metric crates) so the simulator, the
//! server and the benches share one representation. Histograms use
//! log-spaced buckets from 1µs to ~67s, enough resolution for the
//! percentile reporting the paper's evaluation needs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Named monotone counters for one component.
#[derive(Debug, Default)]
pub struct Counters {
    /// Rounds started.
    pub rounds: AtomicU64,
    /// Rounds finished successfully.
    pub commits: AtomicU64,
    /// Ballot conflicts observed.
    pub conflicts: AtomicU64,
    /// Retries performed.
    pub retries: AtomicU64,
    /// 1-RTT cache hits.
    pub cache_hits: AtomicU64,
    /// Requests that failed permanently.
    pub failures: AtomicU64,
    /// Quorum reads served on the 1-RTT zero-write fast path.
    pub read_fast: AtomicU64,
    /// Quorum reads that fell back to the identity-CAS round.
    pub read_fallback: AtomicU64,
    /// Reads served 0-RTT from lease-covered local state (zero
    /// transport sends).
    pub read_lease: AtomicU64,
    /// Lease acquire/renew rounds that armed a full grant set.
    pub lease_renew: AtomicU64,
    /// Leases lost before their window ended (failed renewal, own-write
    /// conflict, config change, GC sync) or found expired on read.
    pub lease_break: AtomicU64,
}

impl Counters {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot as (rounds, commits, conflicts, retries, cache_hits,
    /// failures, read_fast, read_fallback, read_lease, lease_renew,
    /// lease_break).
    pub fn snapshot(&self) -> [u64; 11] {
        [
            self.rounds.load(Ordering::Relaxed),
            self.commits.load(Ordering::Relaxed),
            self.conflicts.load(Ordering::Relaxed),
            self.retries.load(Ordering::Relaxed),
            self.cache_hits.load(Ordering::Relaxed),
            self.failures.load(Ordering::Relaxed),
            self.read_fast.load(Ordering::Relaxed),
            self.read_fallback.load(Ordering::Relaxed),
            self.read_lease.load(Ordering::Relaxed),
            self.lease_renew.load(Ordering::Relaxed),
            self.lease_break.load(Ordering::Relaxed),
        ]
    }
}

/// Counters for one server-edge read coalescer
/// ([`crate::server::ReadCoalescer`]): how many reads rode shared
/// quorum fan-outs, how many fan-outs were dispatched, and how many
/// reads bypassed a full queue. `reads / batches` is the average ride
/// size — 1.0 means no sharing happened (every read led its own
/// fan-out), anything above it is acceptor-side message reduction.
#[derive(Debug, Default)]
pub struct CoalesceStats {
    /// Reads served through coalescer fan-outs (leaders included).
    pub reads: AtomicU64,
    /// Shared quorum fan-outs dispatched.
    pub batches: AtomicU64,
    /// Reads that found the waiting queue full and fell back to their
    /// own per-key quorum round.
    pub overflows: AtomicU64,
}

impl CoalesceStats {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot as (reads, batches, overflows).
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.reads.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.overflows.load(Ordering::Relaxed),
        )
    }

    /// Average reads per dispatched fan-out (0.0 before any dispatch).
    pub fn avg_ride(&self) -> f64 {
        let (reads, batches, _) = self.snapshot();
        if batches == 0 {
            0.0
        } else {
            reads as f64 / batches as f64
        }
    }
}

const BUCKETS: usize = 64;

/// Lock-free log-bucketed latency histogram (microsecond base).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        // bucket i covers [2^i, 2^{i+1}) µs; bucket 0 covers [0, 2).
        (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1)
    }

    /// Records one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    /// Maximum recorded latency.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Approximate quantile (upper bound of the containing bucket).
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1).min(63));
            }
        }
        self.max()
    }

    /// Human-readable one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:?} p50={:?} p99={:?} max={:?}",
            self.count(),
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.99),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_snapshot() {
        let c = Counters::new();
        c.rounds.fetch_add(3, Ordering::Relaxed);
        c.commits.fetch_add(2, Ordering::Relaxed);
        assert_eq!(c.snapshot()[0], 3);
        assert_eq!(c.snapshot()[1], 2);
    }

    #[test]
    fn coalesce_stats_avg_ride() {
        let c = CoalesceStats::new();
        assert_eq!(c.avg_ride(), 0.0, "no dispatches yet");
        c.reads.fetch_add(6, Ordering::Relaxed);
        c.batches.fetch_add(2, Ordering::Relaxed);
        c.overflows.fetch_add(1, Ordering::Relaxed);
        assert_eq!(c.snapshot(), (6, 2, 1));
        assert_eq!(c.avg_ride(), 3.0);
    }

    #[test]
    fn histogram_buckets() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(1000), 9);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for ms in [1u64, 2, 3, 4, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.max() >= Duration::from_millis(100));
        assert!(h.quantile(0.5) >= Duration::from_millis(2));
        assert!(h.quantile(1.0) >= Duration::from_millis(100));
        assert!(!h.summary().is_empty());
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }
}
