//! In-tree micro-benchmark harness (the offline toolchain has no
//! criterion; see DESIGN.md §Substitutions).
//!
//! `cargo bench` targets are `harness = false` binaries built on these
//! helpers: warmup, timed iteration with early cutoff, and mean/p50/p99
//! reporting in criterion-like one-line format.

use std::time::{Duration, Instant};

/// One benchmark's collected samples (nanoseconds per iteration).
pub struct Samples {
    /// Benchmark label.
    pub name: String,
    ns: Vec<u64>,
}

impl Samples {
    /// Mean ns/iter.
    pub fn mean_ns(&self) -> f64 {
        if self.ns.is_empty() {
            return f64::NAN;
        }
        self.ns.iter().sum::<u64>() as f64 / self.ns.len() as f64
    }

    /// Quantile (q in [0,1]) of ns/iter.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.ns.is_empty() {
            return 0;
        }
        let mut sorted = self.ns.clone();
        sorted.sort_unstable();
        let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
        sorted[idx]
    }

    /// Iterations per second implied by the mean.
    pub fn ops_per_sec(&self) -> f64 {
        1e9 / self.mean_ns()
    }

    /// One-line report.
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>12} ns/iter  p50 {:>10}  p99 {:>10}  ({:.0} ops/s, n={})",
            self.name,
            format_ns(self.mean_ns() as u64),
            format_ns(self.quantile_ns(0.5)),
            format_ns(self.quantile_ns(0.99)),
            self.ops_per_sec(),
            self.ns.len()
        )
    }
}

fn format_ns(ns: u64) -> String {
    if ns >= 10_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else if ns >= 10_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Runs `f` repeatedly: `warmup` unmeasured iterations, then measured
/// iterations until `budget` elapses or `max_iters` is reached.
pub fn bench(name: &str, warmup: u32, budget: Duration, max_iters: u64, mut f: impl FnMut()) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut ns = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget && (ns.len() as u64) < max_iters {
        let t = Instant::now();
        f();
        ns.push(t.elapsed().as_nanos() as u64);
    }
    Samples { name: name.to_string(), ns }
}

/// Standard settings: 10 warmup iters, 2s budget, ≤10k iters.
pub fn bench_default(name: &str, f: impl FnMut()) -> Samples {
    bench(name, 10, Duration::from_secs(2), 10_000, f)
}

/// Prints a markdown table row.
pub fn table_row(cells: &[String]) -> String {
    format!("| {} |", cells.join(" | "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = bench("noop", 2, Duration::from_millis(50), 100, || {
            std::hint::black_box(1 + 1);
        });
        assert!(!s.ns.is_empty());
        assert!(s.mean_ns() >= 0.0);
        assert!(s.quantile_ns(0.99) >= s.quantile_ns(0.0));
        assert!(s.report().contains("noop"));
    }

    #[test]
    fn format_ns_ranges() {
        assert!(format_ns(500).ends_with("ns"));
        assert!(format_ns(50_000).ends_with("µs"));
        assert!(format_ns(50_000_000).ends_with("ms"));
    }
}
