//! Change functions (§2.2).
//!
//! Clients mutate a CASPaxos register by submitting **side-effect-free
//! functions** `f(state) -> state`. Because change functions must cross
//! the network (client → proposer), they are represented as a serializable
//! enum rather than closures; [`ChangeFn::apply`] is the single evaluation
//! point, and the L1 Pallas kernel (`apply_cas.py`) implements the same
//! semantics vectorized over a key batch — the two are differential-tested.

use crate::codec::{Codec, CodecError};
use crate::state::{opcode, Val};

/// A serializable, side-effect-free state transition function.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChangeFn {
    /// `x -> x`. Used for reads and for membership-change rescans (§2.3).
    Read,
    /// `x -> if x = ∅ then (0, val) else x` — the paper's *initialize*.
    InitIfEmpty(i64),
    /// `x -> if version(x) = expect then (expect+1, val) else reject` —
    /// the paper's *update if the current version is N* (§2.2).
    Cas {
        /// The version the client read; the update applies only if the
        /// register still carries it.
        expect: i64,
        /// The new numeric payload.
        val: i64,
    },
    /// Unconditional overwrite, bumping the version. Treats ∅/tombstone
    /// as version −1 (so the first Set produces version 0).
    Set(i64),
    /// `x -> (ver+1, num + delta)`; ∅ and tombstone count as 0. This is
    /// the read-increment-write loop of §3.2 collapsed into one
    /// transition — the paper's point that user-defined change functions
    /// merge read-modify-write into a single round.
    Add(i64),
    /// Unconditional overwrite with an opaque payload.
    SetBytes(Vec<u8>),
    /// CAS on an opaque payload.
    CasBytes {
        /// Expected current version.
        expect: i64,
        /// New payload.
        val: Vec<u8>,
    },
    /// `x -> tombstone` — the delete operation (§3.1). The register keeps
    /// occupying space until the GC process removes it.
    Tombstone,
}

/// Result of applying a change function.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Applied {
    /// The state to send in the accept phase.
    pub next: Val,
    /// False when the function rejected the current state (stale CAS).
    /// A rejected change still *reads* — the proposer returns the current
    /// state to the client — but nothing new is accepted... except the
    /// current state itself, which the protocol still writes to fix
    /// partially-accepted older rounds (like the identity transition).
    pub accepted: bool,
}

impl ChangeFn {
    /// Applies the function to the current state. Pure.
    pub fn apply(&self, cur: &Val) -> Applied {
        match self {
            ChangeFn::Read => Applied { next: cur.clone(), accepted: true },
            ChangeFn::InitIfEmpty(v) => {
                if cur.is_empty() || cur.is_tombstone() {
                    Applied { next: Val::Num { ver: 0, num: *v }, accepted: true }
                } else {
                    // Already initialized: the init "succeeds" as a no-op
                    // returning the existing value (paper §2.1 semantics).
                    Applied { next: cur.clone(), accepted: true }
                }
            }
            ChangeFn::Cas { expect, val } => match cur {
                Val::Num { ver, .. } if ver == expect => Applied {
                    next: Val::Num { ver: expect + 1, num: *val },
                    accepted: true,
                },
                _ => Applied { next: cur.clone(), accepted: false },
            },
            ChangeFn::Set(v) => {
                let ver = cur.version().unwrap_or(-1) + 1;
                Applied { next: Val::Num { ver, num: *v }, accepted: true }
            }
            ChangeFn::Add(d) => {
                let (ver, num) = match cur {
                    Val::Num { ver, num } => (*ver, *num),
                    _ => (-1, 0),
                };
                Applied {
                    next: Val::Num { ver: ver + 1, num: num.wrapping_add(*d) },
                    accepted: true,
                }
            }
            ChangeFn::SetBytes(data) => {
                let ver = cur.version().unwrap_or(-1) + 1;
                Applied { next: Val::Bytes { ver, data: data.clone() }, accepted: true }
            }
            ChangeFn::CasBytes { expect, val } => match cur.version() {
                Some(ver) if ver == *expect => Applied {
                    next: Val::Bytes { ver: expect + 1, data: val.clone() },
                    accepted: true,
                },
                _ => Applied { next: cur.clone(), accepted: false },
            },
            ChangeFn::Tombstone => Applied { next: Val::Tombstone, accepted: true },
        }
    }

    /// True if this change is a pure read (no state modification even on
    /// success). Used by the 1-RTT cache and by batching.
    pub fn is_read(&self) -> bool {
        matches!(self, ChangeFn::Read)
    }

    /// The kernel op-code for this change, if it is expressible in the
    /// packed numeric format (`Bytes` variants are not).
    pub fn opcode(&self) -> Option<(i32, [i64; 2])> {
        match self {
            ChangeFn::Read => Some((opcode::READ, [0, 0])),
            ChangeFn::InitIfEmpty(v) => Some((opcode::INIT, [0, *v])),
            ChangeFn::Cas { expect, val } => Some((opcode::CAS, [*expect, *val])),
            ChangeFn::Set(v) => Some((opcode::SET, [0, *v])),
            ChangeFn::Add(d) => Some((opcode::ADD, [0, *d])),
            ChangeFn::Tombstone => Some((opcode::TOMBSTONE, [0, 0])),
            ChangeFn::SetBytes(_) | ChangeFn::CasBytes { .. } => None,
        }
    }
}

impl Codec for ChangeFn {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            ChangeFn::Read => out.push(0),
            ChangeFn::InitIfEmpty(v) => {
                out.push(1);
                v.encode(out);
            }
            ChangeFn::Cas { expect, val } => {
                out.push(2);
                expect.encode(out);
                val.encode(out);
            }
            ChangeFn::Set(v) => {
                out.push(3);
                v.encode(out);
            }
            ChangeFn::Add(d) => {
                out.push(4);
                d.encode(out);
            }
            ChangeFn::SetBytes(data) => {
                out.push(5);
                data.encode(out);
            }
            ChangeFn::CasBytes { expect, val } => {
                out.push(6);
                expect.encode(out);
                val.encode(out);
            }
            ChangeFn::Tombstone => out.push(7),
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(match u8::decode(input)? {
            0 => ChangeFn::Read,
            1 => ChangeFn::InitIfEmpty(i64::decode(input)?),
            2 => ChangeFn::Cas { expect: i64::decode(input)?, val: i64::decode(input)? },
            3 => ChangeFn::Set(i64::decode(input)?),
            4 => ChangeFn::Add(i64::decode(input)?),
            5 => ChangeFn::SetBytes(Vec::<u8>::decode(input)?),
            6 => ChangeFn::CasBytes { expect: i64::decode(input)?, val: Vec::<u8>::decode(input)? },
            7 => ChangeFn::Tombstone,
            _ => return Err(CodecError::Invalid("ChangeFn tag")),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_is_identity() {
        for v in [Val::Empty, Val::Tombstone, Val::Num { ver: 1, num: 2 }] {
            let a = ChangeFn::Read.apply(&v);
            assert_eq!(a.next, v);
            assert!(a.accepted);
        }
    }

    #[test]
    fn init_only_when_empty() {
        let a = ChangeFn::InitIfEmpty(42).apply(&Val::Empty);
        assert_eq!(a.next, Val::Num { ver: 0, num: 42 });
        let existing = Val::Num { ver: 3, num: 7 };
        let a = ChangeFn::InitIfEmpty(42).apply(&existing);
        assert_eq!(a.next, existing, "init over existing value is a no-op read");
    }

    #[test]
    fn init_revives_tombstone() {
        let a = ChangeFn::InitIfEmpty(1).apply(&Val::Tombstone);
        assert_eq!(a.next, Val::Num { ver: 0, num: 1 });
    }

    #[test]
    fn cas_checks_version() {
        let cur = Val::Num { ver: 5, num: 10 };
        let ok = ChangeFn::Cas { expect: 5, val: 11 }.apply(&cur);
        assert!(ok.accepted);
        assert_eq!(ok.next, Val::Num { ver: 6, num: 11 });

        let stale = ChangeFn::Cas { expect: 4, val: 11 }.apply(&cur);
        assert!(!stale.accepted);
        assert_eq!(stale.next, cur, "rejected CAS leaves state unchanged");

        let on_empty = ChangeFn::Cas { expect: 0, val: 1 }.apply(&Val::Empty);
        assert!(!on_empty.accepted, "CAS against ∅ must reject");
    }

    #[test]
    fn add_treats_empty_as_zero() {
        let a = ChangeFn::Add(5).apply(&Val::Empty);
        assert_eq!(a.next, Val::Num { ver: 0, num: 5 });
        let b = ChangeFn::Add(-2).apply(&a.next);
        assert_eq!(b.next, Val::Num { ver: 1, num: 3 });
    }

    #[test]
    fn add_wraps_on_overflow() {
        let cur = Val::Num { ver: 0, num: i64::MAX };
        let a = ChangeFn::Add(1).apply(&cur);
        assert_eq!(a.next.as_num(), Some(i64::MIN));
    }

    #[test]
    fn set_bumps_version() {
        let a = ChangeFn::Set(1).apply(&Val::Empty);
        assert_eq!(a.next, Val::Num { ver: 0, num: 1 });
        let b = ChangeFn::Set(2).apply(&a.next);
        assert_eq!(b.next, Val::Num { ver: 1, num: 2 });
    }

    #[test]
    fn tombstone_always_applies() {
        let a = ChangeFn::Tombstone.apply(&Val::Num { ver: 9, num: 9 });
        assert_eq!(a.next, Val::Tombstone);
        assert!(a.accepted);
    }

    #[test]
    fn bytes_cas() {
        let a = ChangeFn::SetBytes(b"hello".to_vec()).apply(&Val::Empty);
        assert_eq!(a.next.version(), Some(0));
        let ok = ChangeFn::CasBytes { expect: 0, val: b"world".to_vec() }.apply(&a.next);
        assert!(ok.accepted);
        assert_eq!(ok.next.as_bytes(), Some(&b"world"[..]));
        let stale = ChangeFn::CasBytes { expect: 0, val: b"x".to_vec() }.apply(&ok.next);
        assert!(!stale.accepted);
    }

    #[test]
    fn codec_roundtrip() {
        for f in [
            ChangeFn::Read,
            ChangeFn::InitIfEmpty(-4),
            ChangeFn::Cas { expect: 1, val: 2 },
            ChangeFn::Set(9),
            ChangeFn::Add(-1),
            ChangeFn::SetBytes(vec![7; 10]),
            ChangeFn::CasBytes { expect: 0, val: vec![] },
            ChangeFn::Tombstone,
        ] {
            assert_eq!(ChangeFn::from_bytes(&f.to_bytes()).unwrap(), f);
        }
    }

    #[test]
    fn opcodes_cover_numeric_changes() {
        assert!(ChangeFn::Read.opcode().is_some());
        assert!(ChangeFn::Add(1).opcode().is_some());
        assert!(ChangeFn::SetBytes(vec![]).opcode().is_none());
    }
}
