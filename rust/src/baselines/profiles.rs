//! Per-system parameter profiles for the §3.3 comparison table.
//!
//! The paper's table reports one unavailability window per database
//! during a leader-isolation accident, and explicitly warns that the
//! window is "a configuration parameter depending on RTT between nodes"
//! — i.e. dominated by each system's default failure-detection timeout.
//! These profiles pin election-timeout defaults of the same order as the
//! measured windows so the regenerated table reproduces the *shape*:
//! every leader-based system shows a seconds-scale outage, CASPaxos
//! shows zero.

use super::leaderlog::LlConfig;
use crate::sim::{NodeId, SimTime};

/// One comparator system in the §3.3 table.
#[derive(Debug, Clone)]
pub struct SystemProfile {
    /// Display name (matches the paper's table).
    pub name: &'static str,
    /// Replication protocol label (paper column).
    pub protocol: &'static str,
    /// Unavailability window the paper measured (seconds), for the
    /// paper-vs-measured report.
    pub paper_window_s: f64,
    /// Election timeout range (µs) modelling the system's defaults.
    pub election_timeout_us: (SimTime, SimTime),
    /// Heartbeat interval (µs).
    pub heartbeat_us: SimTime,
    /// Per-op server-side processing overhead (µs).
    pub processing_us: SimTime,
}

/// Gryadka's row: CASPaxos, no leader, zero window (measured directly by
/// the CASPaxos sim actors, not via a leader-log profile).
pub const GRYADKA: SystemProfile = SystemProfile {
    name: "Gryadka",
    protocol: "CASPaxos",
    paper_window_s: 0.0,
    election_timeout_us: (0, 0),
    heartbeat_us: 0,
    processing_us: 0,
};

/// Leader-based rows of the paper's table. Election timeouts are set to
/// the order of each system's measured window (the paper's point: the
/// window ≈ detection timeout, a config default, not a protocol merit).
pub const LEADER_BASED: [SystemProfile; 6] = [
    SystemProfile {
        name: "CockroachDB",
        protocol: "MultiRaft",
        paper_window_s: 7.0,
        election_timeout_us: (5_000_000, 9_000_000),
        heartbeat_us: 500_000,
        processing_us: 1_000,
    },
    SystemProfile {
        name: "Consul",
        protocol: "Raft",
        paper_window_s: 14.0,
        election_timeout_us: (10_000_000, 18_000_000),
        heartbeat_us: 1_000_000,
        processing_us: 500,
    },
    SystemProfile {
        name: "Etcd",
        protocol: "Raft",
        paper_window_s: 1.0,
        election_timeout_us: (800_000, 1_200_000),
        heartbeat_us: 100_000,
        processing_us: 500,
    },
    SystemProfile {
        name: "RethinkDB",
        protocol: "Raft",
        paper_window_s: 17.0,
        election_timeout_us: (12_000_000, 22_000_000),
        heartbeat_us: 1_000_000,
        processing_us: 2_000,
    },
    SystemProfile {
        name: "Riak",
        protocol: "Vertical Paxos",
        paper_window_s: 8.0,
        election_timeout_us: (6_000_000, 10_000_000),
        heartbeat_us: 1_000_000,
        processing_us: 2_000,
    },
    SystemProfile {
        name: "TiDB",
        protocol: "MultiRaft",
        paper_window_s: 15.0,
        election_timeout_us: (10_000_000, 20_000_000),
        heartbeat_us: 1_000_000,
        processing_us: 1_000,
    },
];

/// Latency-table comparators (§3.2): Etcd-like and MongoDB-like. The
/// MongoDB profile carries a heavier per-op processing constant (storage
/// engine + majority write/read concern bookkeeping), matching the
/// paper's observation that its measured latency exceeds the pure
/// protocol estimate by a larger margin.
pub fn etcd_like(replicas: Vec<NodeId>, leader: NodeId) -> LlConfig {
    LlConfig {
        replicas,
        initial_leader: leader,
        heartbeat_us: 100_000,
        election_timeout_us: (800_000, 1_200_000),
        processing_us: 500,
    }
}

/// MongoDB-like profile for the §3.2 latency table.
pub fn mongo_like(replicas: Vec<NodeId>, leader: NodeId) -> LlConfig {
    LlConfig {
        replicas,
        initial_leader: leader,
        heartbeat_us: 500_000,
        election_timeout_us: (8_000_000, 12_000_000),
        // The paper measured ~1086ms vs a 676ms protocol estimate for
        // West US 2: ≈410ms of per-iteration (2 ops) implementation
        // overhead — ~200ms per op (majority write concern + storage
        // engine + linearizable read concern bookkeeping).
        processing_us: 200_000,
    }
}

/// Builds an [`LlConfig`] from a §3.3 profile.
pub fn ll_config(p: &SystemProfile, replicas: Vec<NodeId>, leader: NodeId) -> LlConfig {
    LlConfig {
        replicas,
        initial_leader: leader,
        heartbeat_us: p.heartbeat_us,
        election_timeout_us: p.election_timeout_us,
        processing_us: p.processing_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_cover_paper_table() {
        let names: Vec<&str> = LEADER_BASED.iter().map(|p| p.name).collect();
        for expected in ["CockroachDB", "Consul", "Etcd", "RethinkDB", "Riak", "TiDB"] {
            assert!(names.contains(&expected), "missing {expected}");
        }
        assert_eq!(GRYADKA.paper_window_s, 0.0);
    }

    #[test]
    fn election_timeouts_track_measured_windows() {
        for p in &LEADER_BASED {
            let (lo, hi) = p.election_timeout_us;
            assert!(lo < hi);
            // The timeout midpoint is within 3x of the paper's window.
            let mid_s = (lo + hi) as f64 / 2.0 / 1e6;
            assert!(
                mid_s <= p.paper_window_s * 3.0 && mid_s >= p.paper_window_s / 3.0,
                "{}: timeout {mid_s}s vs paper window {}s",
                p.name,
                p.paper_window_s
            );
        }
    }

    #[test]
    fn config_builders() {
        let cfg = ll_config(&LEADER_BASED[2], vec![1, 2, 3], 3);
        assert_eq!(cfg.initial_leader, 3);
        assert_eq!(cfg.election_timeout_us, (800_000, 1_200_000));
        let m = mongo_like(vec![1, 2, 3], 3);
        assert!(m.processing_us > etcd_like(vec![1, 2, 3], 3).processing_us);
    }
}
