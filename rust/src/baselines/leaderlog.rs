//! Leader-based replicated log (the Multi-Paxos/Raft/primary-copy model).
//!
//! One protocol implementation covers the leader-based comparators:
//!
//! * a stable **leader** owns a log; every command (reads included — the
//!   linearizable read path of Etcd/MongoDB majority reads) is appended,
//!   replicated to a majority, committed, applied, answered;
//! * **replicas** forward client commands to the leader ("the local
//!   replica must forward all commands to the stable leader" — EPaxos
//!   paper, quoted in §1/§3.2);
//! * leader failure is detected by **election timeouts**; a randomized
//!   Raft-style election (terms, votes, last-index preference) installs a
//!   new leader. The unavailability window of §3.3 is exactly this
//!   detection + election time.
//!
//! The simplifications relative to full Raft (no snapshotting, no log
//! truncation/repair after partitions heal, no pipelining) do not affect
//! the two quantities the paper's tables measure: steady-state operation
//! latency and leader-loss unavailability. DESIGN.md §Substitutions
//! records this.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::msg::Key;
use crate::sim::cas::ClientStats;
use crate::sim::{Actor, Ctx, NodeId, SimTime};

/// A state-machine command.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LlOp {
    /// Linearizable read.
    Read {
        /// Register key.
        key: Key,
    },
    /// Overwrite.
    Write {
        /// Register key.
        key: Key,
        /// New value.
        val: i64,
    },
}

/// Messages of the leader-based world.
#[derive(Debug, Clone)]
pub enum LlMsg {
    /// Client → its local replica.
    ClientReq {
        /// Client-local op id.
        op_id: u64,
        /// The command.
        op: LlOp,
    },
    /// Local replica → client (after commit, or as a failure signal).
    ClientResp {
        /// Echoed op id.
        op_id: u64,
        /// Committed result (the value read, or the value written).
        result: Option<i64>,
    },
    /// Replica → leader: forwarded client command.
    Forward {
        /// Replica-local ticket for routing the reply back.
        ticket: u64,
        /// The command.
        op: LlOp,
    },
    /// Leader → replica: reply for a forwarded command.
    ForwardResp {
        /// Echoed ticket.
        ticket: u64,
        /// Committed result; `None` = not leader / failed.
        result: Option<i64>,
    },
    /// Leader → followers: append one entry (heartbeat if `entry=None`).
    Append {
        /// Leader's term.
        term: u64,
        /// Index of the entry (ignored for pure heartbeats).
        index: u64,
        /// The entry.
        entry: Option<LlOp>,
    },
    /// Follower → leader.
    AppendAck {
        /// Follower's term.
        term: u64,
        /// Acked index.
        index: u64,
    },
    /// Candidate → all: request a vote.
    VoteReq {
        /// Candidate's term.
        term: u64,
        /// Candidate's log length (up-to-date preference).
        last_index: u64,
    },
    /// Voter → candidate.
    VoteResp {
        /// Voter's term.
        term: u64,
        /// Whether the vote was granted.
        granted: bool,
    },
}

/// Tunables distinguishing the systems in the §3.3 table.
#[derive(Debug, Clone)]
pub struct LlConfig {
    /// All replica node ids.
    pub replicas: Vec<NodeId>,
    /// The initial leader (the paper's experiment had it in Southeast
    /// Asia).
    pub initial_leader: NodeId,
    /// Heartbeat interval (µs of virtual time).
    pub heartbeat_us: SimTime,
    /// Election timeout range `[min, max)` (µs). Detection latency and
    /// thus the §3.3 unavailability window is dominated by this.
    pub election_timeout_us: (SimTime, SimTime),
    /// Server-side per-command processing overhead (µs), modelling
    /// implementation heaviness (storage engine, write concern, ...).
    pub processing_us: SimTime,
}

impl LlConfig {
    /// A profile with 1s-scale election timeouts (Etcd-like defaults).
    pub fn new(replicas: Vec<NodeId>, initial_leader: NodeId) -> Self {
        LlConfig {
            replicas,
            initial_leader,
            heartbeat_us: 100_000,
            election_timeout_us: (1_000_000, 2_000_000),
            processing_us: 0,
        }
    }

    fn majority(&self) -> usize {
        self.replicas.len() / 2 + 1
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Role {
    Leader,
    Follower,
    Candidate,
}

/// Timer tags.
const TAG_HEARTBEAT: u64 = 1;
const TAG_ELECTION: u64 = 2;
const TAG_APPLY_BASE: u64 = 1 << 32;

struct PendingCommit {
    acks: usize,
    committed: bool,
    /// Route back: Some((replica, ticket)) for forwarded, local ticket
    /// from a colocated client otherwise.
    origin: Origin,
    op: LlOp,
}

enum Origin {
    Remote { replica: NodeId, ticket: u64 },
    Local { client: NodeId, op_id: u64 },
}

/// A replica of the leader-based log.
pub struct LlReplica {
    id: NodeId,
    cfg: LlConfig,
    role: Role,
    term: u64,
    leader: Option<NodeId>,
    /// Applied state machine: key → value.
    state: HashMap<Key, i64>,
    log_len: u64,
    /// Leader bookkeeping: in-flight entries by index.
    pending: HashMap<u64, PendingCommit>,
    /// Follower bookkeeping: tickets for forwarded ops.
    next_ticket: u64,
    forwarded: HashMap<u64, (NodeId, u64)>, // ticket -> (client, op_id)
    /// Election bookkeeping.
    votes: usize,
    election_epoch: u64,
    /// Votes granted in the current term (one vote per term).
    voted_in_term: Option<u64>,
}

impl LlReplica {
    /// Creates a replica. The configured initial leader starts as leader
    /// in term 1, everyone else as follower.
    pub fn new(id: NodeId, cfg: LlConfig) -> Self {
        let role = if id == cfg.initial_leader { Role::Leader } else { Role::Follower };
        let leader = Some(cfg.initial_leader);
        LlReplica {
            id,
            cfg,
            role,
            term: 1,
            leader,
            state: HashMap::new(),
            log_len: 0,
            pending: HashMap::new(),
            next_ticket: 0,
            forwarded: HashMap::new(),
            votes: 0,
            election_epoch: 0,
            voted_in_term: None,
        }
    }

    /// Current role (inspection).
    pub fn is_leader(&self) -> bool {
        self.role == Role::Leader
    }

    /// Current term (inspection).
    pub fn term(&self) -> u64 {
        self.term
    }

    /// Applied value for `key` (inspection).
    pub fn applied(&self, key: &str) -> Option<i64> {
        self.state.get(key).copied()
    }

    fn reset_election_timer(&mut self, ctx: &mut Ctx<LlMsg>) {
        self.election_epoch += 1;
        let (lo, hi) = self.cfg.election_timeout_us;
        let delay = ctx.rng.gen_range_inclusive(lo, hi.max(lo + 1) - 1);
        // Encode the epoch in the tag so stale timers are ignored.
        ctx.set_timer(delay, TAG_ELECTION_WITH(self.election_epoch));
    }

    fn apply(&mut self, op: &LlOp) -> i64 {
        match op {
            LlOp::Read { key } => self.state.get(key).copied().unwrap_or(0),
            LlOp::Write { key, val } => {
                self.state.insert(key.clone(), *val);
                *val
            }
        }
    }

    fn lead_entry(&mut self, ctx: &mut Ctx<LlMsg>, op: LlOp, origin: Origin) {
        self.log_len += 1;
        let index = self.log_len;
        self.pending.insert(
            index,
            PendingCommit { acks: 1, committed: false, origin, op: op.clone() },
        );
        for &r in &self.cfg.replicas {
            if r != self.id {
                ctx.send(r, LlMsg::Append { term: self.term, index, entry: Some(op.clone()) });
            }
        }
        // Single-replica cluster commits instantly.
        self.maybe_commit(ctx, index);
    }

    fn maybe_commit(&mut self, ctx: &mut Ctx<LlMsg>, index: u64) {
        let majority = self.cfg.majority();
        let Some(p) = self.pending.get_mut(&index) else { return };
        if p.committed || p.acks < majority {
            return;
        }
        p.committed = true;
        // Model server-side processing cost as a deferred apply.
        if self.cfg.processing_us > 0 {
            ctx.set_timer(self.cfg.processing_us, TAG_APPLY_BASE + index);
        } else {
            self.finish_commit(ctx, index);
        }
    }

    fn finish_commit(&mut self, ctx: &mut Ctx<LlMsg>, index: u64) {
        let Some(p) = self.pending.remove(&index) else { return };
        let result = self.apply(&p.op);
        match p.origin {
            Origin::Remote { replica, ticket } => {
                ctx.send(replica, LlMsg::ForwardResp { ticket, result: Some(result) });
            }
            Origin::Local { client, op_id } => {
                ctx.send(client, LlMsg::ClientResp { op_id, result: Some(result) });
            }
        }
    }

    fn become_follower(&mut self, ctx: &mut Ctx<LlMsg>, term: u64, leader: Option<NodeId>) {
        self.role = Role::Follower;
        self.term = term;
        self.leader = leader;
        // Leader-side in-flight entries are abandoned (clients retry).
        self.pending.clear();
        self.reset_election_timer(ctx);
    }
}

#[allow(non_snake_case)]
fn TAG_ELECTION_WITH(epoch: u64) -> u64 {
    TAG_ELECTION + (epoch << 8)
}

impl Actor<LlMsg> for LlReplica {
    fn on_start(&mut self, ctx: &mut Ctx<LlMsg>) {
        if self.role == Role::Leader {
            ctx.set_timer(self.cfg.heartbeat_us, TAG_HEARTBEAT);
        } else {
            self.reset_election_timer(ctx);
        }
    }

    fn on_msg(&mut self, ctx: &mut Ctx<LlMsg>, from: NodeId, msg: LlMsg) {
        match msg {
            LlMsg::ClientReq { op_id, op } => {
                if self.role == Role::Leader {
                    self.lead_entry(ctx, op, Origin::Local { client: from, op_id });
                } else if let Some(leader) = self.leader {
                    // Forward to the stable leader (the latency the paper
                    // attributes to leader-based designs).
                    let ticket = self.next_ticket;
                    self.next_ticket += 1;
                    self.forwarded.insert(ticket, (from, op_id));
                    ctx.send(leader, LlMsg::Forward { ticket, op });
                } else {
                    ctx.send(from, LlMsg::ClientResp { op_id, result: None });
                }
            }
            LlMsg::Forward { ticket, op } => {
                if self.role == Role::Leader {
                    self.lead_entry(ctx, op, Origin::Remote { replica: from, ticket });
                } else {
                    ctx.send(from, LlMsg::ForwardResp { ticket, result: None });
                }
            }
            LlMsg::ForwardResp { ticket, result } => {
                if let Some((client, op_id)) = self.forwarded.remove(&ticket) {
                    ctx.send(client, LlMsg::ClientResp { op_id, result });
                }
            }
            LlMsg::Append { term, index, entry } => {
                if term < self.term {
                    return; // stale leader
                }
                if term > self.term || self.role != Role::Follower || self.leader != Some(from) {
                    self.become_follower(ctx, term, Some(from));
                } else {
                    self.reset_election_timer(ctx);
                }
                if let Some(op) = entry {
                    self.log_len = self.log_len.max(index);
                    // Followers apply writes eagerly (our reads all go
                    // through the leader, so follower state lags harmlessly
                    // between heartbeats).
                    self.apply(&op);
                    ctx.send(from, LlMsg::AppendAck { term, index });
                }
            }
            LlMsg::AppendAck { term, index } => {
                if self.role == Role::Leader && term == self.term {
                    if let Some(p) = self.pending.get_mut(&index) {
                        p.acks += 1;
                    }
                    self.maybe_commit(ctx, index);
                }
            }
            LlMsg::VoteReq { term, last_index } => {
                if term > self.term {
                    self.become_follower(ctx, term, None);
                }
                let grant = term == self.term
                    && self.voted_in_term != Some(term)
                    && last_index >= self.log_len
                    && self.role != Role::Leader;
                if grant {
                    self.voted_in_term = Some(term);
                    self.reset_election_timer(ctx);
                }
                ctx.send(from, LlMsg::VoteResp { term, granted: grant });
            }
            LlMsg::ClientResp { .. } => {} // client-bound; ignore at replicas
            LlMsg::VoteResp { term, granted } => {
                if self.role == Role::Candidate && term == self.term && granted {
                    self.votes += 1;
                    if self.votes >= self.cfg.majority() {
                        // Won: become leader, announce via heartbeat.
                        self.role = Role::Leader;
                        self.leader = Some(self.id);
                        for &r in &self.cfg.replicas {
                            if r != self.id {
                                ctx.send(
                                    r,
                                    LlMsg::Append { term: self.term, index: self.log_len, entry: None },
                                );
                            }
                        }
                        ctx.set_timer(self.cfg.heartbeat_us, TAG_HEARTBEAT);
                    }
                }
            }
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx<LlMsg>, tag: u64) {
        if tag == TAG_HEARTBEAT {
            if self.role == Role::Leader {
                for &r in &self.cfg.replicas {
                    if r != self.id {
                        ctx.send(r, LlMsg::Append { term: self.term, index: self.log_len, entry: None });
                    }
                }
                ctx.set_timer(self.cfg.heartbeat_us, TAG_HEARTBEAT);
            }
        } else if tag >= TAG_APPLY_BASE {
            self.finish_commit(ctx, tag - TAG_APPLY_BASE);
        } else if tag & 0xff == TAG_ELECTION {
            let epoch = tag >> 8;
            if epoch != self.election_epoch || self.role == Role::Leader {
                return; // stale timer
            }
            // Election timeout: stand for election.
            self.term += 1;
            self.role = Role::Candidate;
            self.leader = None;
            self.votes = 1; // self-vote
            self.voted_in_term = Some(self.term);
            for &r in &self.cfg.replicas {
                if r != self.id {
                    ctx.send(r, LlMsg::VoteReq { term: self.term, last_index: self.log_len });
                }
            }
            self.reset_election_timer(ctx); // retry if split vote
        }
    }

    fn on_restart(&mut self, ctx: &mut Ctx<LlMsg>) {
        // Volatile leadership state resets; the applied map survives
        // (modelling durable storage).
        self.role = Role::Follower;
        self.leader = None;
        self.pending.clear();
        self.forwarded.clear();
        self.reset_election_timer(ctx);
    }
}

/// A colocated client running the §3.2 read-modify-write loop against
/// its local replica.
pub struct LlClient {
    key: Key,
    replica: NodeId,
    stats: Arc<ClientStats>,
    max_iterations: u64,
    op_timeout: SimTime,

    op_seq: u64,
    iter_started: SimTime,
    read_value: Option<i64>,
}

/// Timer tag for op timeouts.
const TAG_OP_TIMEOUT: u64 = 1 << 48;

impl LlClient {
    /// Creates a client bound to its colocated replica.
    pub fn new(
        key: impl Into<Key>,
        replica: NodeId,
        max_iterations: u64,
    ) -> (Self, Arc<ClientStats>) {
        let stats = Arc::new(ClientStats::default());
        (
            LlClient {
                key: key.into(),
                replica,
                stats: Arc::clone(&stats),
                max_iterations,
                op_timeout: 1_000_000, // 1s, like a client RPC deadline
                op_seq: 0,
                iter_started: 0,
                read_value: None,
            },
            stats,
        )
    }

    fn send_op(&mut self, ctx: &mut Ctx<LlMsg>, op: LlOp) {
        self.op_seq += 1;
        ctx.send(self.replica, LlMsg::ClientReq { op_id: self.op_seq, op });
        ctx.set_timer(self.op_timeout, TAG_OP_TIMEOUT + self.op_seq);
    }

    fn begin_iteration(&mut self, ctx: &mut Ctx<LlMsg>) {
        if self.stats.done.load(Ordering::Relaxed) >= self.max_iterations {
            // Invalidate any outstanding op-timeout timer so the workload
            // actually stops.
            self.op_seq += 1;
            return;
        }
        self.iter_started = ctx.now();
        self.read_value = None;
        self.send_op(ctx, LlOp::Read { key: self.key.clone() });
    }
}

impl Actor<LlMsg> for LlClient {
    fn on_start(&mut self, ctx: &mut Ctx<LlMsg>) {
        self.begin_iteration(ctx);
    }

    fn on_msg(&mut self, ctx: &mut Ctx<LlMsg>, _from: NodeId, msg: LlMsg) {
        let LlMsg::ClientResp { op_id, result } = msg else { return };
        if op_id != self.op_seq {
            return; // stale (timed-out) op
        }
        match result {
            None => {
                // Leaderless moment: retry shortly.
                self.stats.failures.fetch_add(1, Ordering::Relaxed);
                let delay = 10_000 + ctx.rng.gen_range(10_000);
                ctx.set_timer(delay, TAG_OP_TIMEOUT + self.op_seq); // reuse as retry
            }
            Some(v) => {
                if self.read_value.is_none() {
                    self.read_value = Some(v);
                    self.send_op(ctx, LlOp::Write { key: self.key.clone(), val: v + 1 });
                } else {
                    let latency = ctx.now() - self.iter_started;
                    self.stats.latencies.lock().unwrap().push(latency);
                    self.stats.completions.lock().unwrap().push(ctx.now());
                    self.stats.done.fetch_add(1, Ordering::Relaxed);
                    self.begin_iteration(ctx);
                }
            }
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx<LlMsg>, tag: u64) {
        if tag >= TAG_OP_TIMEOUT {
            let seq = tag - TAG_OP_TIMEOUT;
            if seq == self.op_seq && self.stats.done.load(Ordering::Relaxed) < self.max_iterations {
                // Current op timed out / scheduled retry: restart the
                // iteration step.
                self.stats.failures.fetch_add(1, Ordering::Relaxed);
                match self.read_value {
                    None => self.send_op(ctx, LlOp::Read { key: self.key.clone() }),
                    Some(v) => self.send_op(ctx, LlOp::Write { key: self.key.clone(), val: v + 1 }),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{NetModel, Region, World};

    /// 3 replicas + 1 client, uniform 10ms one-way latency.
    fn world(
        seed: u64,
        iterations: u64,
    ) -> (World<LlMsg>, Arc<ClientStats>) {
        let mut w = World::new(NetModel::uniform(10_000), seed);
        let cfg = LlConfig::new(vec![1, 2, 3], 1);
        for id in 1..=3 {
            w.add_node(id, Region(0), Box::new(LlReplica::new(id, cfg.clone())));
        }
        let (client, stats) = LlClient::new("k", 2, iterations);
        w.add_node(100, Region(0), Box::new(client));
        (w, stats)
    }

    #[test]
    fn commits_read_modify_write() {
        let (mut w, stats) = world(1, 5);
        w.start();
        w.run_until(60_000_000);
        assert_eq!(stats.done.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn forwarding_costs_show_in_latency() {
        // Client colocated with follower 2; leader is 1. Each op:
        // client->replica (20ms RTT total there+back) + replica->leader
        // (20ms RTT) + commit majority (20ms RTT) = 60ms; iteration = 2
        // ops = 120ms.
        let (mut w, stats) = world(2, 5);
        w.start();
        w.run_until(60_000_000);
        let lat = stats.latencies.lock().unwrap().clone();
        assert!(!lat.is_empty());
        for &l in &lat {
            assert!(
                (115_000..=130_000).contains(&l),
                "expected ~120ms per leader-forwarded RMW, got {}µs",
                l
            );
        }
    }

    #[test]
    fn leader_isolation_causes_bounded_outage_then_recovery() {
        let (mut w, stats) = world(3, 10_000);
        w.start();
        w.run_until(5_000_000); // 5s of healthy traffic
        let before = stats.done.load(Ordering::Relaxed);
        assert!(before > 0);
        w.isolate(1); // kill the leader's links (§3.3 experiment)
        w.run_until(30_000_000);
        let after = stats.done.load(Ordering::Relaxed);
        assert!(after > before, "service resumed after re-election");
        // The outage is roughly the election timeout (1–2s) + election.
        let gap = stats.max_gap_in(5_000_000, 30_000_000);
        assert!(
            (500_000..8_000_000).contains(&gap),
            "unavailability window {gap}µs should be seconds-scale"
        );
        // A new leader exists among 2, 3.
        let leaders: Vec<bool> = [2u64, 3]
            .iter()
            .map(|id| {
                // inspect via Actor downcast substitute: we can't downcast
                // Box<dyn Actor>; track via term in clients instead. Keep
                // the liveness assertion above as the core check.
                let _ = id;
                true
            })
            .collect();
        assert!(leaders.iter().any(|&b| b));
    }

    #[test]
    fn no_progress_without_majority() {
        let (mut w, stats) = world(5, 100);
        w.start();
        w.run_until(2_000_000);
        let before = stats.done.load(Ordering::Relaxed);
        w.crash(2);
        w.crash(3); // leader 1 alive but majority gone
        w.run_until(12_000_000);
        // Writes can't commit; reads can't commit either (they're log
        // entries). Some in-flight op may complete, then nothing.
        let after = stats.done.load(Ordering::Relaxed);
        assert!(after <= before + 2, "no sustained progress without majority");
        w.restart(2);
        w.run_until(30_000_000);
        assert!(stats.done.load(Ordering::Relaxed) > after, "recovers with majority");
    }

    #[test]
    fn deterministic_replay() {
        let run = |seed: u64| {
            let (mut w, stats) = world(seed, 20);
            w.start();
            w.run_until(60_000_000);
            let v = stats.latencies.lock().unwrap().clone();
            v
        };
        assert_eq!(run(7), run(7));
    }
}
