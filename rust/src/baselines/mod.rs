//! Comparator protocols for the paper's evaluation tables.
//!
//! The paper compares Gryadka (CASPaxos) against leader-based systems:
//! Etcd/Consul/… (Raft), MongoDB (primary-copy), CockroachDB/TiDB
//! (MultiRaft), Riak (Vertical Paxos). Reproducing those exact codebases
//! is out of scope; what the tables measure is *protocol structure* —
//! where the leader sits, how many RTTs an operation costs, how long
//! re-election takes. The substitution (DESIGN.md): one faithful
//! leader-based replicated-log implementation, [`leaderlog`],
//! parameterized by the per-system defaults that differ (election
//! timeout, heartbeat interval, server-side processing overhead), running
//! on the same simulator as CASPaxos.
//!
//! [`profiles`] pins one parameter set per system in the §3.3 table.

pub mod leaderlog;
pub mod profiles;
