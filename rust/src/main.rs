//! `caspaxos` — cluster launcher and client CLI.
//!
//! ```text
//! caspaxos node --id 1 --config cluster.conf \
//!     [--listen-client 0.0.0.0:8101] [--data /var/lib/caspaxos]
//! caspaxos node --id 1 --peers 1=h1:7101,2=h2:7101,3=h3:7101 ...
//! caspaxos client --connect host:8101 get <key>
//! caspaxos client --connect host:8101 set <key> <num>
//! caspaxos client --connect host:8101 add <key> <delta>
//! caspaxos client --connect host:8101 cas <key> <expect_ver> <num>
//! caspaxos client --connect host:8101 del <key>
//! caspaxos client --connect host:8101 collect | status
//! caspaxos rtt-table      # print the paper's §3.2 RTT matrix (E1)
//! ```
//!
//! Argument parsing is hand-rolled (the offline toolchain has no clap);
//! see DESIGN.md §Substitutions.

use std::collections::HashMap;
use std::process::exit;

use caspaxos::change::ChangeFn;
use caspaxos::config::Deployment;
use caspaxos::server::{start_node, Client, ClientReq, ClientResp, NodeOpts};

fn usage() -> ! {
    eprintln!(
        "usage:\n  caspaxos node --id <n> (--config <file> | --peers <1=a,2=b,...>)\n\
         \x20                [--listen-client <addr>] [--data <dir>] [--stripes <n>]\n\
         \x20                [--proposers <n>] [--io-threads <n>] [--max-deferred <n>]\n\
         \x20                [--checkpoint-records <n>] [--checkpoint-bytes <n>]\n\
         \x20                [--backend mem|disk] [--read-coalesce on|off]\n\
         \x20                [--coalesce-queue <n>]\n\
         \x20 caspaxos client --connect <addr> \
         <get|getcas|getmany|set|add|cas|del|collect|status> [args...]\n\
         \x20 caspaxos rtt-table"
    );
    exit(2)
}

fn take_flag(args: &mut Vec<String>, name: &str) -> Option<String> {
    let idx = args.iter().position(|a| a == name)?;
    if idx + 1 >= args.len() {
        eprintln!("missing value for {name}");
        usage();
    }
    args.remove(idx);
    Some(args.remove(idx))
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    match args.remove(0).as_str() {
        "node" => run_node(args),
        "client" => run_client(args),
        "rtt-table" => print!("{}", caspaxos::wan::rtt_table()),
        _ => usage(),
    }
}

fn run_node(mut args: Vec<String>) {
    let id: u64 = take_flag(&mut args, "--id")
        .unwrap_or_else(|| usage())
        .parse()
        .unwrap_or_else(|_| usage());
    // What the config file (or bare peer list) contributes before
    // command-line flags override it.
    struct Parsed {
        peers: HashMap<u64, String>,
        quorum: Option<caspaxos::quorum::QuorumSpec>,
        shard_plan: Option<caspaxos::shard::ShardPlan>,
        stripes: usize,
        proposers: usize,
        io_threads: usize,
        max_deferred: usize,
        checkpoint: Option<caspaxos::acceptor::CheckpointOpts>,
        backend: caspaxos::acceptor::Backend,
        read_coalesce: bool,
        coalesce_queue: usize,
    }
    let cfg = if let Some(path) = take_flag(&mut args, "--config") {
        let d = Deployment::load(&path).unwrap_or_else(|e| {
            eprintln!("config: {e}");
            exit(1)
        });
        let plan = d.shard_plan().unwrap_or_else(|e| {
            eprintln!("shard plan: {e}");
            exit(1)
        });
        Parsed {
            peers: d.peers.clone(),
            quorum: Some(d.quorum),
            shard_plan: if d.shards > 1 { Some(plan) } else { None },
            stripes: d.stripes,
            proposers: d.proposers,
            io_threads: d.io_threads,
            max_deferred: d.max_deferred,
            checkpoint: d.checkpoint_opts(),
            backend: d.backend,
            read_coalesce: d.read_coalesce,
            coalesce_queue: d.coalesce_queue,
        }
    } else if let Some(spec) = take_flag(&mut args, "--peers") {
        let peers = Deployment::parse_peers(&spec).unwrap_or_else(|e| {
            eprintln!("peers: {e}");
            exit(1)
        });
        Parsed {
            peers,
            quorum: None,
            shard_plan: None,
            stripes: 1,
            proposers: 1,
            io_threads: 1,
            max_deferred: 256,
            checkpoint: None,
            backend: caspaxos::acceptor::Backend::default(),
            read_coalesce: false,
            coalesce_queue: 64,
        }
    } else {
        usage()
    };
    let Parsed {
        peers,
        quorum,
        shard_plan,
        stripes: cfg_stripes,
        proposers: cfg_proposers,
        io_threads: cfg_io_threads,
        max_deferred: cfg_max_deferred,
        checkpoint: cfg_checkpoint,
        backend: cfg_backend,
        read_coalesce: cfg_read_coalesce,
        coalesce_queue: cfg_coalesce_queue,
    } = cfg;
    // `--stripes` overrides the config's `stripes` directive.
    let stripes: usize = match take_flag(&mut args, "--stripes") {
        Some(n) => {
            let n = n.parse().unwrap_or_else(|_| usage());
            if n == 0 {
                eprintln!("--stripes must be at least 1");
                exit(1)
            }
            n
        }
        None => cfg_stripes,
    };
    // `--io-threads` / `--max-deferred` override the config's
    // directives (event-loop thread budget per served listener and the
    // per-connection deferred-reply cap — see server::NodeOpts).
    let core_flag = |args: &mut Vec<String>, name: &str, cfg: usize| -> usize {
        match take_flag(args, name) {
            Some(n) => {
                let n = n.parse().unwrap_or_else(|_| usage());
                if n == 0 {
                    eprintln!("{name} must be at least 1");
                    exit(1)
                }
                n
            }
            None => cfg,
        }
    };
    let io_threads = core_flag(&mut args, "--io-threads", cfg_io_threads);
    let max_deferred = core_flag(&mut args, "--max-deferred", cfg_max_deferred);
    // `--proposers` overrides the config's `proposers` directive (the
    // per-shard proposer-pool size behind the request router; capped
    // at 5 by start_node).
    let proposers = core_flag(&mut args, "--proposers", cfg_proposers);
    let Some(acceptor_addr) = peers.get(&id).cloned() else {
        eprintln!("node id {id} not in peer map");
        exit(1)
    };
    let client_addr =
        take_flag(&mut args, "--listen-client").unwrap_or_else(|| "0.0.0.0:0".to_string());
    // Peer client/admin addresses for cross-node GC sync (id=addr list).
    let client_peers = match take_flag(&mut args, "--client-peers") {
        Some(spec) => Deployment::parse_peers(&spec).unwrap_or_else(|e| {
            eprintln!("client-peers: {e}");
            exit(1)
        }),
        None => HashMap::new(),
    };
    let data_dir = take_flag(&mut args, "--data");
    // `--checkpoint-records` / `--checkpoint-bytes` override the
    // config's directives (either nonzero threshold enables the
    // online auto-checkpoint poller; only meaningful with --data).
    let ckpt_flag = |args: &mut Vec<String>, name: &str| -> Option<u64> {
        take_flag(args, name).map(|n| n.parse().unwrap_or_else(|_| usage()))
    };
    let ckpt_records = ckpt_flag(&mut args, "--checkpoint-records");
    let ckpt_bytes = ckpt_flag(&mut args, "--checkpoint-bytes");
    let checkpoint = if ckpt_records.is_some() || ckpt_bytes.is_some() {
        let base = cfg_checkpoint.unwrap_or_default();
        Some(caspaxos::acceptor::CheckpointOpts {
            interval_records: ckpt_records.unwrap_or(base.interval_records),
            interval_bytes: ckpt_bytes.unwrap_or(base.interval_bytes),
        })
    } else {
        cfg_checkpoint
    };
    // `--backend` overrides the config's `backend` directive (slot-map
    // residency for the durable tier; only meaningful with --data).
    let backend = match take_flag(&mut args, "--backend") {
        Some(b) => caspaxos::acceptor::Backend::parse(&b).unwrap_or_else(|| {
            eprintln!("--backend must be `mem` or `disk`");
            exit(1)
        }),
        None => cfg_backend,
    };
    // `--read-coalesce` / `--coalesce-queue` override the config's
    // directives (server-edge ride-sharing of independent reads into
    // shared quorum fan-outs — see server::ReadCoalescer).
    let read_coalesce = match take_flag(&mut args, "--read-coalesce") {
        Some(v) => match v.as_str() {
            "on" => true,
            "off" => false,
            _ => {
                eprintln!("--read-coalesce must be `on` or `off`");
                exit(1)
            }
        },
        None => cfg_read_coalesce,
    };
    let coalesce_queue = core_flag(&mut args, "--coalesce-queue", cfg_coalesce_queue);

    let mut acceptors: Vec<u64> = peers.keys().copied().collect();
    acceptors.sort_unstable();
    let cluster = match quorum {
        Some(q) => caspaxos::quorum::ClusterConfig { epoch: 1, acceptors, quorum: q },
        None => caspaxos::quorum::ClusterConfig::majority(1, acceptors),
    };
    cluster.validate().unwrap_or_else(|e| {
        eprintln!("cluster config: {e}");
        exit(1)
    });

    let shards = shard_plan.as_ref().map(|p| p.shard_count()).unwrap_or(1);
    let node = start_node(NodeOpts {
        id,
        acceptor_addr,
        client_addr,
        peers,
        client_peers,
        cluster,
        shard_plan,
        stripes,
        io_threads,
        max_deferred,
        data_dir,
        checkpoint,
        backend,
        lease: None,
        proposers_per_shard: proposers,
        router: caspaxos::router::RouterOpts::default(),
        read_coalesce,
        coalesce_queue,
    })
    .unwrap_or_else(|e| {
        eprintln!("start_node: {e}");
        exit(1)
    });
    println!(
        "caspaxos node {id}: acceptor on {}, clients on {} \
         ({shards} shard(s), {stripes} stripe(s), {proposers} proposer(s)/shard)",
        node.acceptor_addr, node.client_addr
    );
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn run_client(mut args: Vec<String>) {
    let addr = take_flag(&mut args, "--connect").unwrap_or_else(|| usage());
    let mut client = Client::connect(&addr).unwrap_or_else(|e| {
        eprintln!("connect: {e}");
        exit(1)
    });
    if args.is_empty() {
        usage();
    }
    let cmd = args.remove(0);
    let req = match (cmd.as_str(), args.as_slice()) {
        // Fast-path read (1-RTT quorum read, identity-CAS fallback).
        ("get", [key]) => ClientReq::Read { key: key.clone() },
        // Ablation: force the classic identity-CAS read round.
        ("getcas", [key]) => ClientReq::Change { key: key.clone(), change: ChangeFn::Read },
        // Batched reads sharing one quorum-read fan-out per shard.
        ("getmany", keys) if !keys.is_empty() => {
            ClientReq::ReadBatch { keys: keys.to_vec() }
        }
        ("set", [key, num]) => ClientReq::Change {
            key: key.clone(),
            change: ChangeFn::Set(num.parse().unwrap_or_else(|_| usage())),
        },
        ("add", [key, delta]) => ClientReq::Change {
            key: key.clone(),
            change: ChangeFn::Add(delta.parse().unwrap_or_else(|_| usage())),
        },
        ("cas", [key, expect, num]) => ClientReq::Change {
            key: key.clone(),
            change: ChangeFn::Cas {
                expect: expect.parse().unwrap_or_else(|_| usage()),
                val: num.parse().unwrap_or_else(|_| usage()),
            },
        },
        ("del", [key]) => ClientReq::Delete { key: key.clone() },
        ("collect", []) => ClientReq::Collect,
        ("status", []) => ClientReq::Status,
        _ => usage(),
    };
    match client.call(&req) {
        Ok(ClientResp::Val(v)) => println!("{v}"),
        Ok(ClientResp::Status(s)) => println!("{s}"),
        Ok(ClientResp::Batch(items)) => {
            for item in items {
                match item {
                    Ok(v) => println!("{v}"),
                    Err(e) => println!("error: {e}"),
                }
            }
        }
        Ok(ClientResp::Synced { proposer_id, age }) => {
            println!("synced proposer {proposer_id} to age {age}")
        }
        Ok(ClientResp::Err(e)) => {
            eprintln!("error: {e}");
            exit(1);
        }
        Err(e) => {
            eprintln!("transport: {e}");
            exit(1);
        }
    }
}
