//! The Gryadka-equivalent node: acceptor service + client-facing
//! proposer service on one process.
//!
//! A deployment runs one `caspaxos node` per machine (2F+1 of them).
//! Each node serves:
//!
//! * the **acceptor protocol** (proposer→acceptor [`Request`]s) on the
//!   acceptor port — consumed by every node's proposers;
//! * the **client protocol** ([`ClientReq`]/[`ClientResp`], same
//!   correlation-id envelope framing as the acceptor protocol) on the
//!   client port — consumed by applications. Any node serves any
//!   client: there is no leader (§3.2, §3.3). Requests on one
//!   connection are handled **concurrently** and replies return in
//!   completion order, matched by correlation id — a slow `Change`
//!   never head-of-line blocks a `Read` multiplexed beside it.
//!
//! Client batches route through the PJRT data plane ([`BatchProposer`])
//! when AOT artifacts are available, scalar fallback otherwise.
//!
//! ## Sharded acceptor groups
//!
//! With a [`ShardPlan`] in [`NodeOpts::shard_plan`], the node runs one
//! proposer (and one batch proposer) **per shard**, each bound to that
//! shard's disjoint acceptor group, and routes every client key through
//! the rendezvous [`ShardRouter`]. The acceptor service is unchanged —
//! a node hosts one acceptor, and which shard that acceptor belongs to
//! is entirely a property of the plan. Deletion GC collects each key
//! against its owning group only ([`GcProcess::collect_all_with`]).
//!
//! ## Striped acceptor core
//!
//! Orthogonally, [`NodeOpts::stripes`] lock-stripes the node's OWN
//! acceptor ([`StripedAcceptor`]): requests on independent keys are
//! handled under independent locks while every stripe appends into one
//! shared group-commit WAL, so a multi-client write load scales across
//! cores without multiplying fsyncs. `Status` exports the shared WAL's
//! `wal_appends`/`wal_fsyncs` (their gap is the group-commit win) and
//! the transport's `inflight` depth (proposer-side backpressure).
//!
//! ## Server-edge read coalescing
//!
//! With [`NodeOpts::read_coalesce`], independent `ClientReq::Read`s
//! merge into shared quorum fan-outs through a per-shard
//! [`ReadCoalescer`] — a ride-sharing scheme with **no fixed window**:
//! an uncontended read dispatches immediately (zero idle-latency tax),
//! and only reads arriving while a fan-out is already in flight queue
//! to share the next one. Reads covered by a live 0-RTT lease window
//! are served locally and never queued, and lease-mode misses keep the
//! redirect-aware path (the denial names the holder). `Status` exports
//! `reads_coalesced=`/`coalesce_batches=`/`coalesce_avg=`.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};

use crate::acceptor::{
    Backend, CheckpointOpts, CkptStats, GroupCommitOpts, StripedAcceptor, WalStats,
    DISK_CACHE_SLOTS,
};
use crate::batch::BatchProposer;
use crate::change::ChangeFn;
use crate::codec::{decode_seq, encode_seq, Codec, CodecError, Envelope};
use crate::error::{CasError, CasResult};
use crate::gc::GcProcess;
use crate::metrics::CoalesceStats;
use crate::msg::Key;
use crate::proposer::Proposer;
use crate::quorum::ClusterConfig;
use crate::router::{Router, RouterOpts};
use crate::runtime::auto_engine;
use crate::shard::{ShardPlan, ShardRouter};
use crate::state::Val;
use crate::transport::tcp::{
    read_frame, serve_service, serve_striped_acceptor_opts, write_envelope, Handled, LoopStats,
    ServeOpts, ServiceHandler, TcpTransport,
};

/// Client-facing request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClientReq {
    /// Apply one change function to one register.
    Change {
        /// Register key.
        key: Key,
        /// The change.
        change: ChangeFn,
    },
    /// Apply a batch of changes to distinct registers (PJRT data plane).
    Batch {
        /// (key, change) pairs; keys must be distinct and changes
        /// kernel-expressible.
        ops: Vec<(Key, ChangeFn)>,
    },
    /// Delete a key (tombstone now, GC later).
    Delete {
        /// Register key.
        key: Key,
    },
    /// Run the deletion GC queue once.
    Collect,
    /// Liveness/metrics probe.
    Status,
    /// Admin (node→node): GC step 2b on this node's proposer (§3.1).
    GcSync {
        /// Register being collected.
        key: Key,
        /// Tombstone ballot counter to fast-forward past.
        min_counter: u64,
    },
    /// Linearizable read, routed to the key's shard proposer. Served on
    /// the 1-RTT zero-write quorum-read fast path when possible, with
    /// the identity-CAS fallback otherwise (see
    /// [`crate::proposer::ReadMode`]).
    Read {
        /// Register key.
        key: Key,
    },
    /// Batched linearizable reads: split by shard, each shard's keys
    /// share ONE quorum-read fan-out
    /// ([`BatchProposer::read_batch_merged`]; duplicate keys collapse
    /// into one fan-out column, one result per position either way).
    ReadBatch {
        /// Register keys (duplicates allowed).
        keys: Vec<Key>,
    },
}

impl Codec for ClientReq {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            ClientReq::Change { key, change } => {
                out.push(0);
                key.encode(out);
                change.encode(out);
            }
            ClientReq::Batch { ops } => {
                out.push(1);
                encode_seq(ops, out);
            }
            ClientReq::Delete { key } => {
                out.push(2);
                key.encode(out);
            }
            ClientReq::Collect => out.push(3),
            ClientReq::Status => out.push(4),
            ClientReq::GcSync { key, min_counter } => {
                out.push(5);
                key.encode(out);
                min_counter.encode(out);
            }
            ClientReq::Read { key } => {
                out.push(6);
                key.encode(out);
            }
            ClientReq::ReadBatch { keys } => {
                out.push(7);
                encode_seq(keys, out);
            }
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(match u8::decode(input)? {
            0 => ClientReq::Change { key: Key::decode(input)?, change: ChangeFn::decode(input)? },
            1 => ClientReq::Batch { ops: decode_seq(input)? },
            2 => ClientReq::Delete { key: Key::decode(input)? },
            3 => ClientReq::Collect,
            4 => ClientReq::Status,
            5 => ClientReq::GcSync { key: Key::decode(input)?, min_counter: u64::decode(input)? },
            6 => ClientReq::Read { key: Key::decode(input)? },
            7 => ClientReq::ReadBatch { keys: decode_seq(input)? },
            _ => return Err(CodecError::Invalid("ClientReq tag")),
        })
    }
}

/// Client-facing response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClientResp {
    /// The resulting state of a change.
    Val(Val),
    /// Per-op results of a batch (error text for failed slots).
    Batch(Vec<Result<Val, String>>),
    /// Status string (metrics snapshot).
    Status(String),
    /// GcSync acknowledgement: (proposer id, new age).
    Synced {
        /// The synced proposer's id.
        proposer_id: u64,
        /// Its age after the bump.
        age: u64,
    },
    /// Request failed.
    Err(String),
}

impl Codec for ClientResp {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            ClientResp::Val(v) => {
                out.push(0);
                v.encode(out);
            }
            ClientResp::Batch(items) => {
                out.push(1);
                items.len().encode(out);
                for item in items {
                    match item {
                        Ok(v) => {
                            out.push(0);
                            v.encode(out);
                        }
                        Err(e) => {
                            out.push(1);
                            e.encode(out);
                        }
                    }
                }
            }
            ClientResp::Status(s) => {
                out.push(2);
                s.encode(out);
            }
            ClientResp::Err(e) => {
                out.push(3);
                e.encode(out);
            }
            ClientResp::Synced { proposer_id, age } => {
                out.push(4);
                proposer_id.encode(out);
                age.encode(out);
            }
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self, CodecError> {
        Ok(match u8::decode(input)? {
            0 => ClientResp::Val(Val::decode(input)?),
            1 => {
                let n = usize::decode(input)?;
                if n > crate::codec::MAX_LEN {
                    return Err(CodecError::Invalid("length bomb"));
                }
                let mut items = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    items.push(match u8::decode(input)? {
                        0 => Ok(Val::decode(input)?),
                        1 => Err(String::decode(input)?),
                        _ => return Err(CodecError::Invalid("result tag")),
                    });
                }
                ClientResp::Batch(items)
            }
            2 => ClientResp::Status(String::decode(input)?),
            3 => ClientResp::Err(String::decode(input)?),
            4 => ClientResp::Synced { proposer_id: u64::decode(input)?, age: u64::decode(input)? },
            _ => return Err(CodecError::Invalid("ClientResp tag")),
        })
    }
}

/// A peer node's proposer, reachable over its client/admin port.
/// Implements [`crate::gc::ProposerAdmin`] so a node's GC can run step
/// 2b on EVERY proposer in the deployment — without this, a peer's
/// 1-RTT cache could resurrect a deleted register (the lost-delete
/// anomaly; reproduced by `full_node_cluster_serves_clients` before the
/// remote sync existed).
pub struct RemoteProposer {
    /// The peer's proposer id.
    pub proposer_id: u64,
    /// The peer's client/admin address.
    pub addr: String,
}

impl crate::gc::ProposerAdmin for RemoteProposer {
    fn id(&self) -> u64 {
        self.proposer_id
    }
    fn gc_sync(&self, key: &Key, min_counter: u64) -> CasResult<(u64, u64)> {
        let mut client = Client::connect(&self.addr)?;
        match client.call(&ClientReq::GcSync { key: key.clone(), min_counter })? {
            // A sharded peer syncs ALL its shard proposers and reports
            // the (id, age) of the one owning `key` — exactly what the
            // collector must fence on the key's acceptor group.
            ClientResp::Synced { proposer_id, age } => Ok((proposer_id, age)),
            other => Err(CasError::Transport(format!("GcSync: unexpected {other:?}"))),
        }
    }
}

/// Default [`NodeOpts::coalesce_queue`]: followers parked per shard
/// before reads bypass to their own rounds.
const DEFAULT_COALESCE_QUEUE: usize = 64;

/// What a queued read receives from the flight ahead of it.
enum Ride {
    /// The leader fanned out for this waiter; here is its column's
    /// result.
    Served(CasResult<Val>),
    /// The previous flight completed and this waiter is the oldest in
    /// the queue: it becomes the next leader and fans out for itself
    /// plus these co-riders.
    Lead(Vec<Waiter>),
}

/// One read parked while a fan-out is in flight.
struct Waiter {
    key: Key,
    tx: mpsc::Sender<Ride>,
}

/// Server-edge read coalescer: merges independent client reads into
/// shared quorum fan-outs (ride-sharing over
/// [`BatchProposer::read_batch_merged`]).
///
/// The coalescing window is **adaptive** — no timer, no fixed sleep.
/// The first read to arrive at an idle coalescer becomes the *leader*
/// and dispatches its fan-out immediately, so an uncontended read pays
/// nothing. Reads arriving while a fan-out is in flight park as
/// *followers*; when the flight lands, its leader hands the whole
/// accumulated queue to the oldest follower, which leads ONE shared
/// fan-out covering every queued key (duplicates collapse into one
/// column — the hot-key best case). Under R concurrent readers the
/// acceptor-side cost per ride generation drops from `R × A` messages
/// to one shared fan-out, and the queue drains at one quorum RTT per
/// generation regardless of R.
///
/// The no-stale-ride rule is structural: followers are collected into
/// a ride BEFORE it dispatches, so a read enqueued after a write was
/// acked is only ever served by a fan-out dispatched after that write
/// — late joiners ride the *next* flight, never the stale in-flight
/// one (`tests/tcp_chaos.rs` pins this with a gated acceptor).
///
/// A full queue ([`NodeOpts::coalesce_queue`]) bypasses with
/// [`CasError::Overloaded`] instead of parking; the server then falls
/// back to a plain per-key routed read, trading message reduction for
/// liveness under pathological bursts.
pub struct ReadCoalescer {
    inner: Mutex<CoalesceInner>,
    max_queue: usize,
    /// Rides/fan-outs/overflows, exported through `Status` as
    /// `reads_coalesced=` / `coalesce_batches=` / `coalesce_avg=`.
    pub stats: CoalesceStats,
}

struct CoalesceInner {
    /// A fan-out is currently in flight (its leader will hand off).
    in_flight: bool,
    /// Reads parked for the next flight, oldest first.
    queue: Vec<Waiter>,
}

impl ReadCoalescer {
    /// A coalescer admitting at most `max_queue` parked followers
    /// (minimum 1; reads past the cap bypass with `Overloaded`).
    pub fn new(max_queue: usize) -> Self {
        ReadCoalescer {
            inner: Mutex::new(CoalesceInner { in_flight: false, queue: Vec::new() }),
            max_queue: max_queue.max(1),
            stats: CoalesceStats::new(),
        }
    }

    /// Followers currently parked (tests/diagnostics).
    pub fn queued(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// One linearizable read through the coalescer: leads immediately
    /// when idle, otherwise rides a shared fan-out. Returns
    /// [`CasError::Overloaded`] without fanning out when the queue is
    /// full — the caller pays its own per-key round instead.
    pub fn read(&self, key: Key, batch: &BatchProposer) -> CasResult<Val> {
        let rx = {
            let mut inner = self.inner.lock().unwrap();
            if !inner.in_flight {
                inner.in_flight = true;
                None
            } else if inner.queue.len() >= self.max_queue {
                self.stats.overflows.fetch_add(1, Ordering::Relaxed);
                return Err(CasError::Overloaded {
                    inflight: self.max_queue,
                    max: self.max_queue,
                });
            } else {
                let (tx, rx) = mpsc::channel();
                inner.queue.push(Waiter { key: key.clone(), tx });
                Some(rx)
            }
        };
        let Some(rx) = rx else {
            return self.lead(key, Vec::new(), batch);
        };
        match rx.recv() {
            Ok(Ride::Served(res)) => res,
            Ok(Ride::Lead(riders)) => self.lead(key, riders, batch),
            // The leader panicked mid-flight and this waiter's sender
            // unwound with its stack (the handoff guard already elected
            // a leader from the reads still queued). Serve solo.
            Err(_) => {
                let mut results = batch.read_batch_merged(std::slice::from_ref(&key))?;
                results.remove(0)
            }
        }
    }

    /// Dispatches ONE shared fan-out for `key` plus every co-rider's
    /// key and demultiplexes the per-column results back to the riders.
    /// On every exit — success, error, even an unwinding panic — the
    /// queue accumulated during the flight is handed to the next
    /// leader (or `in_flight` clears); a dying leader must never
    /// strand the coalescer with the flag stuck set.
    fn lead(&self, key: Key, riders: Vec<Waiter>, batch: &BatchProposer) -> CasResult<Val> {
        struct Handoff<'a>(&'a ReadCoalescer);
        impl Drop for Handoff<'_> {
            fn drop(&mut self) {
                let mut inner = self.0.inner.lock().unwrap();
                loop {
                    if inner.queue.is_empty() {
                        inner.in_flight = false;
                        return;
                    }
                    let mut group = std::mem::take(&mut inner.queue);
                    let next = group.remove(0);
                    match next.tx.send(Ride::Lead(group)) {
                        // in_flight stays true: the new leader owns it.
                        Ok(()) => return,
                        // The elected leader's receiver is gone (its
                        // worker died); re-queue the co-riders and try
                        // the next-oldest.
                        Err(mpsc::SendError(Ride::Lead(rest))) => inner.queue = rest,
                        Err(_) => unreachable!("handoff sends only Ride::Lead"),
                    }
                }
            }
        }
        let _handoff = Handoff(self);
        let mut keys: Vec<Key> = Vec::with_capacity(1 + riders.len());
        keys.push(key);
        keys.extend(riders.iter().map(|w| w.key.clone()));
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        self.stats.reads.fetch_add(keys.len() as u64, Ordering::Relaxed);
        match batch.read_batch_merged(&keys) {
            Ok(mut results) => {
                let mine = results.remove(0);
                for (w, res) in riders.into_iter().zip(results) {
                    let _ = w.tx.send(Ride::Served(res));
                }
                mine
            }
            Err(e) => {
                for w in riders {
                    let _ = w.tx.send(Ride::Served(Err(e.clone())));
                }
                Err(e)
            }
        }
    }
}

/// Options for one node process.
#[derive(Debug, Clone)]
pub struct NodeOpts {
    /// This node's id (also its acceptor id and proposer id).
    pub id: u64,
    /// Acceptor listen address.
    pub acceptor_addr: String,
    /// Client listen address.
    pub client_addr: String,
    /// Acceptor id → acceptor address for the whole cluster.
    pub peers: HashMap<u64, String>,
    /// Peer node id → client/admin address (for cross-node GC sync).
    /// May omit this node; single-node setups may leave it empty.
    pub client_peers: HashMap<u64, String>,
    /// Protocol cluster config (the whole acceptor set; used verbatim
    /// when `shard_plan` is `None`).
    pub cluster: ClusterConfig,
    /// Acceptor sharding. `None` = one shard over `cluster` (classic).
    pub shard_plan: Option<ShardPlan>,
    /// Acceptor lock-stripe count: this node's registers spread over
    /// `stripes` independently locked slot maps sharing ONE group-commit
    /// WAL ([`StripedAcceptor`]), so requests on independent keys never
    /// contend on the acceptor lock while their records still coalesce
    /// under one fsync. `0` is treated as 1 (the classic single-lock
    /// acceptor; on-disk format unchanged). Orthogonal to `shard_plan`:
    /// shards scale the CLUSTER across disjoint acceptor groups,
    /// stripes scale ONE node across cores.
    pub stripes: usize,
    /// Durable storage directory (`None` = in-memory).
    pub data_dir: Option<String>,
    /// Slot storage backend for data-dir nodes. [`Backend::Mem`]
    /// (default) rebuilds resident per-stripe maps from checkpoint +
    /// WAL replay; [`Backend::Disk`] keeps slots in per-stripe segment
    /// files behind a bounded cache ([`crate::acceptor::DiskStorage`]),
    /// so the keyspace can exceed RAM. Same WAL/checkpoint files either
    /// way — a node may switch backends across restarts. Ignored
    /// without `data_dir`. `Status` exports `backend=` plus the disk
    /// backend's `resident_keys=`/`index_pages=` gauges.
    pub backend: Backend,
    /// Automatic checkpoint cadence for the file-backed log (`None` =
    /// no automatic checkpoints; ignored without `data_dir`). When the
    /// WAL has grown past either threshold since the last checkpoint, a
    /// background thread runs the online coordination point
    /// ([`StripedAcceptor::compact`]): quiesce every stripe, write a
    /// full-state checkpoint beside the WAL, swap in a truncated WAL —
    /// so restart replays only the delta and the log reclaims disk
    /// without a restart. `Status` exports `checkpoint_records=` /
    /// `replay_records=` / `last_checkpoint_us=`.
    pub checkpoint: Option<CheckpointOpts>,
    /// Enable 0-RTT read leases on every shard proposer (each becomes
    /// the per-shard lease manager for the keys it owns). `None` =
    /// 1-RTT quorum reads (the default).
    pub lease: Option<crate::proposer::LeaseOpts>,
    /// Event-loop threads per served listener (acceptor service and
    /// client service each get their own loops). `0` is treated as 1.
    /// Only the Linux epoll core consults this; the threaded fallback
    /// spawns per connection. Raise it when one loop thread saturates
    /// a core under many active connections.
    pub io_threads: usize,
    /// Per-connection cap on in-flight deferred replies (both server
    /// cores): past it the connection stops reading until a reply
    /// completes. `0` is treated as the default 256.
    pub max_deferred: usize,
    /// Proposers per shard in this node's request tier
    /// ([`crate::router`]): each shard runs a pool of interchangeable
    /// proposers and the router spreads distinct keys across them, so
    /// request throughput scales independently of the acceptor count.
    /// `0` is treated as 1 (the classic fused path); capped at 5 by the
    /// proposer-id block layout.
    pub proposers_per_shard: usize,
    /// Routing-tier tunables: lease-redirect budget and the background
    /// renewal cadence ([`RouterOpts`]).
    pub router: RouterOpts,
    /// Server-edge read coalescing ([`ReadCoalescer`]): merge
    /// independent client reads into shared per-shard quorum fan-outs.
    /// Adaptive (an uncontended read dispatches immediately — no idle
    /// window tax); worth enabling when many clients read concurrently,
    /// worth disabling when reads are rare and latency-critical enough
    /// that even one mutex handoff matters. Default off.
    pub read_coalesce: bool,
    /// Max reads parked per shard coalescer waiting for the next shared
    /// fan-out; past it reads bypass to their own per-key round. `0` is
    /// treated as the default (64). Ignored unless `read_coalesce`.
    pub coalesce_queue: usize,
}

/// A running node (handles held for inspection; threads detached).
pub struct Node {
    /// Bound acceptor address.
    pub acceptor_addr: std::net::SocketAddr,
    /// Bound client address.
    pub client_addr: std::net::SocketAddr,
    /// The shard-0 pool-0 proposer (the only one in unsharded,
    /// unpooled deployments).
    pub proposer: Arc<Proposer>,
    /// The first pool member per shard, indexed by shard id.
    pub shard_proposers: Vec<Arc<Proposer>>,
    /// The request tier: per-shard proposer pools behind the stateless
    /// router ([`crate::router`]).
    pub router: Arc<Router>,
    /// The node's GC process.
    pub gc: Arc<GcProcess>,
    /// Acceptor lock-stripe count this node runs with.
    pub stripes: usize,
    /// Checkpoint-poller shutdown: flag + join handle, stopped on drop
    /// so a dropped node's poller can never truncate a log that a
    /// restarted node (same data dir, same process — tests do this)
    /// now owns.
    ckpt_stop: Option<(Arc<std::sync::atomic::AtomicBool>, std::thread::JoinHandle<()>)>,
    /// Per-shard lease-renewal timers, stopped and joined on drop (a
    /// dropped node's timers must not keep renewing leases its
    /// restarted successor now manages).
    renew_stop: Option<(Arc<std::sync::atomic::AtomicBool>, Vec<std::thread::JoinHandle<()>>)>,
}

impl Drop for Node {
    fn drop(&mut self) {
        if let Some((stop, handle)) = self.ckpt_stop.take() {
            stop.store(true, std::sync::atomic::Ordering::Release);
            let _ = handle.join();
        }
        if let Some((stop, handles)) = self.renew_stop.take() {
            stop.store(true, std::sync::atomic::Ordering::Release);
            for handle in handles {
                let _ = handle.join();
            }
        }
    }
}

/// Everything the client service needs to route a request: the key→shard
/// router plus the per-shard protocol handles.
struct NodeCtx {
    router: ShardRouter,
    shards: Vec<ClusterConfig>,
    /// First pool member per shard (the batch/inflight anchors).
    proposers: Vec<Arc<Proposer>>,
    /// The request tier: every client key routes through here.
    request_router: Arc<Router>,
    batches: Vec<Arc<BatchProposer>>,
    gc: Arc<GcProcess>,
    /// Acceptor lock-stripe count (exported through `Status`).
    stripes: usize,
    /// Effective slot backend (exported through `Status`; always
    /// [`Backend::Mem`] without a data dir).
    backend: Backend,
    /// Shared-WAL + checkpoint counter snapshot for `Status`
    /// (file-backed acceptors only; every stripe appends to the one
    /// WAL, so this IS the aggregate across stripes).
    wal_stats: Option<Arc<dyn Fn() -> (WalStats, CkptStats) + Send + Sync>>,
    /// Disk-backend gauges for `Status` (`resident_keys`,
    /// `index_pages`); `None` reports zeros.
    backend_stats: Option<Arc<dyn Fn() -> (usize, u64) + Send + Sync>>,
    /// Server-core counters shared by this node's acceptor and client
    /// services (exported through `Status` as `open_conns=` /
    /// `loop_wakeups=` / `io_threads=`).
    loop_stats: Arc<LoopStats>,
    /// Per-shard read coalescers (`None` = coalescing disabled; plain
    /// reads go straight to the request router).
    coalescers: Option<Vec<Arc<ReadCoalescer>>>,
}

/// Spawns the checkpoint poller: the striped coordination point must
/// run OUTSIDE the request path (it takes every stripe lock), so a
/// thread polls WAL growth and fires the online pause-write-swap when
/// a threshold is crossed. Backend-agnostic — callers pass closures
/// over their acceptor handle. It stops when the `Node` drops — a
/// poller outliving its node would keep truncating a log another
/// (restarted) node now owns.
fn spawn_checkpoint_poller(
    copts: CheckpointOpts,
    due: impl Fn(&CheckpointOpts) -> bool + Send + 'static,
    compact: impl Fn() -> CasResult<()> + Send + 'static,
) -> (Arc<std::sync::atomic::AtomicBool>, std::thread::JoinHandle<()>) {
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let flag = Arc::clone(&stop);
    let handle = std::thread::spawn(move || {
        while !flag.load(std::sync::atomic::Ordering::Acquire) {
            std::thread::sleep(std::time::Duration::from_millis(50));
            if flag.load(std::sync::atomic::Ordering::Acquire) {
                break;
            }
            if due(&copts) {
                if let Err(e) = compact() {
                    eprintln!("checkpoint: {e}");
                }
            }
        }
    });
    (stop, handle)
}

/// Starts acceptor + client services; returns the bound addresses.
pub fn start_node(opts: NodeOpts) -> CasResult<Node> {
    // ---- acceptor service ----
    let acceptor_listener = TcpListener::bind(&opts.acceptor_addr)
        .map_err(|e| CasError::Transport(format!("bind {}: {e}", opts.acceptor_addr)))?;
    let acceptor_addr =
        acceptor_listener.local_addr().map_err(|e| CasError::Transport(e.to_string()))?;
    let stripes = opts.stripes.max(1);
    // One LoopStats for the whole node: the acceptor and client
    // services aggregate their connection/wakeup counters here, and
    // `Status` reads them back.
    let loop_stats = Arc::new(LoopStats::default());
    let coalesce_queue =
        if opts.coalesce_queue == 0 { DEFAULT_COALESCE_QUEUE } else { opts.coalesce_queue };
    let serve_opts = ServeOpts {
        io_threads: opts.io_threads.max(1),
        max_deferred: if opts.max_deferred == 0 {
            ServeOpts::default().max_deferred
        } else {
            opts.max_deferred
        },
        // Coalescer followers PARK inside deferred-reply workers until
        // their shared fan-out lands; raise the pool cap by the queue
        // depth so a full ride can park without starving unrelated
        // deferred work (writes, batches) of workers.
        workers: ServeOpts::default().workers
            + if opts.read_coalesce { coalesce_queue } else { 0 },
        ..ServeOpts::default()
    };
    let mut ckpt_stop: Option<(Arc<std::sync::atomic::AtomicBool>, std::thread::JoinHandle<()>)> =
        None;
    let mut backend_stats: Option<Arc<dyn Fn() -> (usize, u64) + Send + Sync>> = None;
    // A poll-worthy checkpoint cadence (either threshold set).
    let ckpt_opts = opts
        .checkpoint
        .filter(|c| c.interval_records > 0 || c.interval_bytes > 0);
    // The backend only matters with a data dir (mem nodes have no
    // slots to place); report what actually runs.
    let backend = if opts.data_dir.is_some() { opts.backend } else { Backend::Mem };
    let wal_stats: Option<Arc<dyn Fn() -> (WalStats, CkptStats) + Send + Sync>> = match &opts
        .data_dir
    {
        Some(dir) => {
            std::fs::create_dir_all(dir)
                .map_err(|e| CasError::Transport(format!("mkdir {dir}: {e}")))?;
            let log = format!("{dir}/acceptor-{}.log", opts.id);
            match backend {
                Backend::Mem => {
                    let acc = Arc::new(StripedAcceptor::open(
                        opts.id,
                        log,
                        GroupCommitOpts::default(),
                        stripes,
                    )?);
                    let serve = Arc::clone(&acc);
                    let sopts = serve_opts.clone();
                    let stats = Arc::clone(&loop_stats);
                    std::thread::spawn(move || {
                        let _ = serve_striped_acceptor_opts(
                            acceptor_listener,
                            serve,
                            None,
                            sopts,
                            stats,
                        );
                    });
                    if let Some(copts) = ckpt_opts {
                        let due = Arc::clone(&acc);
                        let cmp = Arc::clone(&acc);
                        ckpt_stop = Some(spawn_checkpoint_poller(
                            copts,
                            move |o| due.checkpoint_due(o),
                            move || cmp.compact(),
                        ));
                    }
                    Some(Arc::new(move || (acc.wal_stats(), acc.ckpt_stats())))
                }
                Backend::Disk => {
                    let acc = Arc::new(StripedAcceptor::open_disk(
                        opts.id,
                        log,
                        GroupCommitOpts::default(),
                        stripes,
                        DISK_CACHE_SLOTS,
                    )?);
                    let serve = Arc::clone(&acc);
                    let sopts = serve_opts.clone();
                    let stats = Arc::clone(&loop_stats);
                    std::thread::spawn(move || {
                        let _ = serve_striped_acceptor_opts(
                            acceptor_listener,
                            serve,
                            None,
                            sopts,
                            stats,
                        );
                    });
                    if let Some(copts) = ckpt_opts {
                        let due = Arc::clone(&acc);
                        let cmp = Arc::clone(&acc);
                        ckpt_stop = Some(spawn_checkpoint_poller(
                            copts,
                            move |o| due.checkpoint_due(o),
                            move || cmp.compact(),
                        ));
                    }
                    let gauges = Arc::clone(&acc);
                    backend_stats =
                        Some(Arc::new(move || (gauges.resident_keys(), gauges.index_pages())));
                    Some(Arc::new(move || (acc.wal_stats(), acc.ckpt_stats())))
                }
            }
        }
        None => {
            let acc = Arc::new(StripedAcceptor::new_mem(opts.id, stripes));
            let sopts = serve_opts.clone();
            let stats = Arc::clone(&loop_stats);
            std::thread::spawn(move || {
                let _ = serve_striped_acceptor_opts(acceptor_listener, acc, None, sopts, stats);
            });
            None
        }
    };

    // ---- per-shard proposers + batchers + gc over the peer transport ----
    let mut peers = opts.peers.clone();
    peers.insert(opts.id, acceptor_addr.to_string());
    let transport = Arc::new(TcpTransport::new(peers));
    let plan = match &opts.shard_plan {
        Some(plan) => plan.clone(),
        None => ShardPlan::single(opts.cluster.clone()),
    };
    plan.validate()?;
    let engine = auto_engine();
    let mut shard_proposers: Vec<Arc<Proposer>> = Vec::new();
    let mut batches: Vec<Arc<BatchProposer>> = Vec::new();
    let proposer_opts = match &opts.lease {
        Some(lease) => crate::proposer::ProposerOpts {
            read_mode: crate::proposer::ReadMode::Lease,
            lease: lease.clone(),
            ..Default::default()
        },
        None => crate::proposer::ProposerOpts::default(),
    };
    let pool_size = opts.proposers_per_shard.max(1);
    if pool_size > 5 {
        // Pool members live in per-member 100k id blocks; member 5
        // would collide with the batch proposers' 500k block.
        return Err(CasError::Config(format!(
            "proposers_per_shard is capped at 5, got {pool_size}"
        )));
    }
    let mut pools: Vec<Vec<Arc<Proposer>>> = Vec::new();
    for (s, cfg) in plan.shards.iter().enumerate() {
        // Proposer ids must be globally unique per (node, shard, pool
        // member). Shard 0 member 0 keeps the historical `id == node
        // id`, so unsharded single-proposer deployments are identical
        // to the pre-shard ones; extra pool members get per-member
        // 100k blocks and batch proposers live in their own 500k block
        // (assumes node ids < 1000, shards < ~100).
        let pid = opts.id + (s as u64) * 1000;
        let pool: Vec<Arc<Proposer>> = (0..pool_size)
            .map(|m| {
                Arc::new(Proposer::with_opts(
                    pid + (m as u64) * 100_000,
                    cfg.clone(),
                    transport.clone(),
                    proposer_opts.clone(),
                ))
            })
            .collect();
        shard_proposers.push(pool[0].clone());
        pools.push(pool);
        batches.push(Arc::new(BatchProposer::new(
            500_000 + pid,
            cfg.clone(),
            transport.clone(),
            Arc::clone(&engine),
        )));
    }
    let request_router = Arc::new(Router::new(pools, opts.router.clone()));
    // Distinct GC-proposer id per node (two GCs must never share
    // ballot identity). The GC must sync EVERY pool member — a skipped
    // member's 1-RTT cache could resurrect a deleted register.
    let gc = Arc::new(GcProcess::with_id(
        transport,
        request_router.all_proposers(),
        900_000 + opts.id,
    ));
    for (&peer_id, addr) in &opts.client_peers {
        if peer_id != opts.id {
            gc.add_admin(Box::new(RemoteProposer { proposer_id: peer_id, addr: addr.clone() }));
        }
    }
    // Per-shard background lease renewal (no-op unless the router opts
    // set a cadence): stopped and joined when the Node drops.
    let renew_stop = {
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let handles = request_router.spawn_renewal(Arc::clone(&stop));
        if handles.is_empty() { None } else { Some((stop, handles)) }
    };
    // One coalescer per shard: rides never span shards (a shard's keys
    // share one acceptor group and one BatchProposer).
    let coalescers = opts.read_coalesce.then(|| {
        (0..plan.shard_count())
            .map(|_| Arc::new(ReadCoalescer::new(coalesce_queue)))
            .collect::<Vec<_>>()
    });
    let ctx = Arc::new(NodeCtx {
        router: ShardRouter::new(plan.shard_count()),
        shards: plan.shards.clone(),
        proposers: shard_proposers.clone(),
        request_router: Arc::clone(&request_router),
        batches,
        gc: Arc::clone(&gc),
        stripes,
        backend,
        wal_stats,
        backend_stats,
        loop_stats: Arc::clone(&loop_stats),
        coalescers,
    });

    // ---- client service ----
    let client_listener = TcpListener::bind(&opts.client_addr)
        .map_err(|e| CasError::Transport(format!("bind {}: {e}", opts.client_addr)))?;
    let client_addr =
        client_listener.local_addr().map_err(|e| CasError::Transport(e.to_string()))?;
    {
        let handler = client_handler(Arc::clone(&ctx));
        let sopts = serve_opts;
        let stats = loop_stats;
        std::thread::spawn(move || {
            let _ = serve_service(client_listener, handler, sopts, stats);
        });
    }
    Ok(Node {
        acceptor_addr,
        client_addr,
        proposer: shard_proposers[0].clone(),
        shard_proposers,
        router: request_router,
        gc,
        stripes,
        ckpt_stop,
        renew_stop,
    })
}

/// The client-service handler, served on the same server core as the
/// acceptor service ([`serve_service`]): `Status` (which never runs a
/// proposer round) is answered inline; every other request runs off the
/// read path — client ops run whole proposer rounds, seconds in the
/// worst case, and a slow `Change` must never head-of-line block a
/// `Read` multiplexed on the same connection.
fn client_handler(ctx: Arc<NodeCtx>) -> ServiceHandler<ClientReq, ClientResp> {
    Arc::new(move |req: ClientReq| {
        if matches!(req, ClientReq::Status) {
            return Handled::Inline(handle_client(&req, &ctx));
        }
        let ctx = Arc::clone(&ctx);
        Handled::Deferred(Box::new(move || {
            // The connection and socket outlive the reply worker, so a
            // handler panic must still produce a reply — the blocking
            // Client would otherwise wait forever for this corr id.
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handle_client(&req, &ctx)))
                .unwrap_or_else(|_| ClientResp::Err("request handler panicked".into()))
        }))
    })
}

fn handle_client(req: &ClientReq, ctx: &NodeCtx) -> ClientResp {
    match req {
        ClientReq::Change { key, change } => {
            match ctx.request_router.change_detailed(key, change.clone()) {
                Ok(out) if out.accepted => ClientResp::Val(out.state),
                Ok(out) => ClientResp::Err(format!("rejected; current state is {}", out.state)),
                Err(e) => ClientResp::Err(e.to_string()),
            }
        }
        ClientReq::Batch { ops } => handle_batch(ops, ctx),
        ClientReq::Read { key } => match read_one(key, ctx) {
            Ok(v) => ClientResp::Val(v),
            Err(e) => ClientResp::Err(e.to_string()),
        },
        ClientReq::ReadBatch { keys } => handle_read_batch(keys, ctx),
        ClientReq::Delete { key } => match ctx.request_router.delete(key) {
            Ok(_) => {
                ctx.gc.schedule(key.clone());
                ClientResp::Val(Val::Tombstone)
            }
            Err(e) => ClientResp::Err(e.to_string()),
        },
        ClientReq::Collect => {
            // Each key is collected against its OWNING acceptor group;
            // collecting against the union would smear registers onto
            // foreign shards.
            let (ok, superseded, failed) =
                ctx.gc.collect_all_with(|key| ctx.shards[ctx.router.route(key)].clone());
            ClientResp::Status(format!("collected={ok} superseded={superseded} failed={failed}"))
        }
        ClientReq::GcSync { key, min_counter } => {
            // Sync EVERY pool member of every shard on this node (caches
            // and ballot counters are per-proposer state), but report the
            // member the router would pick for the key: its age is what
            // the collector fences on the key's acceptor group.
            let own = ctx.request_router.proposer_for(key).id();
            let mut synced = (own, 0);
            for p in ctx.request_router.all_proposers() {
                let age = p.gc_sync(key, *min_counter);
                if p.id() == own {
                    synced = (own, age);
                }
            }
            ClientResp::Synced { proposer_id: synced.0, age: synced.1 }
        }
        ClientReq::Status => {
            let mut snap = [0u64; 11];
            for p in ctx.request_router.all_proposers() {
                for (acc, v) in snap.iter_mut().zip(p.metrics.snapshot()) {
                    *acc += v;
                }
            }
            // Batched reads land on the batch proposers' counters.
            for b in &ctx.batches {
                snap[6] += b.metrics.read_fast.load(std::sync::atomic::Ordering::Relaxed);
                snap[7] += b.metrics.read_fallback.load(std::sync::atomic::Ordering::Relaxed);
            }
            // Shared-WAL + checkpoint counters (file-backed nodes; one
            // WAL serves every stripe, so this IS the per-stripe
            // aggregate) and the proposer-side in-flight depth
            // (backpressure gauge).
            let (wal, ckpt) = ctx.wal_stats.as_ref().map(|f| f()).unwrap_or((
                WalStats { appends: 0, flushes: 0, fsyncs: 0 },
                CkptStats {
                    checkpoint_records: 0,
                    replay_records: 0,
                    replay_truncated_bytes: 0,
                    last_checkpoint_us: 0,
                    checkpoints: 0,
                },
            ));
            let (resident_keys, index_pages) =
                ctx.backend_stats.as_ref().map(|f| f()).unwrap_or((0, 0));
            let inflight = ctx.proposers[0].transport_inflight().unwrap_or(0);
            let (open_conns, loop_wakeups, io_threads) = ctx.loop_stats.snapshot();
            let (routed, redirected) = ctx.request_router.stats();
            // Coalescer counters summed across shards (zeros when
            // coalescing is off); avg is reads per dispatched fan-out.
            let (co_reads, co_batches) = ctx
                .coalescers
                .as_deref()
                .unwrap_or(&[])
                .iter()
                .fold((0u64, 0u64), |(r, b), c| {
                    let (reads, batches, _) = c.stats.snapshot();
                    (r + reads, b + batches)
                });
            let co_avg = if co_batches == 0 { 0.0 } else { co_reads as f64 / co_batches as f64 };
            ClientResp::Status(format!(
                "id={} shards={} rounds={} commits={} conflicts={} retries={} \
                 cache_hits={} failures={} read_fast={} read_fallback={} \
                 read_lease={} lease_renew={} lease_break={} gc_pending={} \
                 stripes={} wal_appends={} wal_flushes={} wal_fsyncs={} \
                 checkpoint_records={} replay_records={} last_checkpoint_us={} \
                 replay_truncated_bytes={} backend={} resident_keys={} \
                 index_pages={} inflight={} \
                 open_conns={} loop_wakeups={} io_threads={} \
                 routed={} redirected={} pool_size={} \
                 reads_coalesced={} coalesce_batches={} coalesce_avg={:.2}",
                ctx.proposers[0].id(),
                ctx.shards.len(),
                snap[0],
                snap[1],
                snap[2],
                snap[3],
                snap[4],
                snap[5],
                snap[6],
                snap[7],
                snap[8],
                snap[9],
                snap[10],
                ctx.gc.pending(),
                ctx.stripes,
                wal.appends,
                wal.flushes,
                wal.fsyncs,
                ckpt.checkpoint_records,
                ckpt.replay_records,
                ckpt.last_checkpoint_us,
                ckpt.replay_truncated_bytes,
                ctx.backend,
                resident_keys,
                index_pages,
                inflight,
                open_conns,
                loop_wakeups,
                io_threads,
                routed,
                redirected,
                ctx.request_router.pool_size(),
                co_reads,
                co_batches,
                co_avg
            ))
        }
    }
}

/// One client read through the tiered read path:
///
/// 1. **0-RTT lease window** — a live local lease serves immediately
///    and never queues (coalescing a read that costs zero messages
///    would only add latency).
/// 2. **Coalesced 1-RTT quorum read** — with [`NodeOpts::read_coalesce`]
///    on a quorum-tier deployment, the read leads or rides a shared
///    per-shard fan-out ([`ReadCoalescer`]). A full queue bypasses to
///    tier 3.
/// 3. **Routed read** — the classic redirect-aware path
///    ([`Router::get`]): per-key quorum read with identity-CAS
///    fallback; in lease mode, denials follow the named holder.
///
/// Lease-mode misses skip tier 2 entirely: their value usually lives
/// behind a redirect to the holder's 0-RTT state, which the coalescer's
/// shared CAS-fallback machinery cannot follow.
fn read_one(key: &Key, ctx: &NodeCtx) -> CasResult<Val> {
    let Some(coalescers) = &ctx.coalescers else {
        return ctx.request_router.get(key);
    };
    if let Some(v) = ctx.request_router.lease_probe(key) {
        return Ok(v);
    }
    if ctx.request_router.uses_leases() {
        return ctx.request_router.get(key);
    }
    let shard = ctx.router.route(key);
    match coalescers[shard].read(key.clone(), &ctx.batches[shard]) {
        // Queue full: pay our own round rather than park.
        Err(CasError::Overloaded { .. }) => ctx.request_router.get(key),
        other => other,
    }
}

/// Splits `n` op indices across shards by routed key.
fn split_by_shard<'a>(
    ctx: &NodeCtx,
    keys: impl Iterator<Item = &'a Key>,
) -> Vec<Vec<usize>> {
    let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); ctx.shards.len()];
    for (i, key) in keys.enumerate() {
        by_shard[ctx.router.route(key)].push(i);
    }
    by_shard
}

/// Runs one closure per non-empty shard **concurrently** and scatters
/// each shard's per-op results back into original batch order. Shards
/// are independent acceptor groups, so a multi-shard batch costs the
/// slowest single shard's RTT, not the sum across shards (the
/// sequential dispatch this replaces paid S serial quorum RTTs for an
/// S-shard `getmany`). A panicking shard worker yields per-op errors
/// for its slots only.
fn scatter_shards(
    n_ops: usize,
    by_shard: &[Vec<usize>],
    run: impl Fn(usize, &[usize]) -> Vec<Result<Val, String>> + Sync,
) -> ClientResp {
    let mut results: Vec<Option<Result<Val, String>>> = Vec::new();
    results.resize_with(n_ops, || None);
    let run = &run;
    let shard_outs: Vec<(usize, Vec<Result<Val, String>>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = by_shard
            .iter()
            .enumerate()
            .filter(|(_, idxs)| !idxs.is_empty())
            .map(|(s, idxs)| (s, scope.spawn(move || run(s, idxs))))
            .collect();
        handles
            .into_iter()
            .map(|(s, h)| {
                let out = h.join().unwrap_or_else(|_| {
                    by_shard[s].iter().map(|_| Err("shard batch worker panicked".into())).collect()
                });
                (s, out)
            })
            .collect()
    });
    for (s, out) in shard_outs {
        for (&i, r) in by_shard[s].iter().zip(out) {
            results[i] = Some(r);
        }
    }
    ClientResp::Batch(results.into_iter().map(|r| r.expect("every slot routed")).collect())
}

/// Executes a client batch, splitting it across shards when needed
/// (each non-empty shard dispatched concurrently) and reassembling
/// per-op results in the original order.
fn handle_batch(ops: &[(Key, ChangeFn)], ctx: &NodeCtx) -> ClientResp {
    if ctx.shards.len() == 1 {
        return match ctx.batches[0].execute(ops) {
            Ok(results) => ClientResp::Batch(
                results.into_iter().map(|r| r.map_err(|e| e.to_string())).collect(),
            ),
            Err(e) => ClientResp::Err(e.to_string()),
        };
    }
    let by_shard = split_by_shard(ctx, ops.iter().map(|(key, _)| key));
    scatter_shards(ops.len(), &by_shard, |s, idxs| {
        let shard_ops: Vec<(Key, ChangeFn)> = idxs.iter().map(|&i| ops[i].clone()).collect();
        match ctx.batches[s].execute(&shard_ops) {
            Ok(rs) => rs.into_iter().map(|r| r.map_err(|e| e.to_string())).collect(),
            Err(e) => {
                // Other shards' ops may already be durably applied, so a
                // whole-batch error would hide partial application (and
                // invite unsafe retries of non-idempotent ops). Report
                // the failure per-op instead.
                let msg = e.to_string();
                idxs.iter().map(|_| Err(msg.clone())).collect()
            }
        }
    })
}

/// Executes a client read batch: each shard's keys share one
/// quorum-read fan-out ([`BatchProposer::read_batch_merged`], so
/// duplicate client keys collapse rather than erroring), non-empty
/// shards dispatch concurrently, and results reassemble in the
/// original order. Whole-shard failures report **per-op** on every
/// shape — including the single-shard case, which used to collapse
/// into one `ClientResp::Err` while the multi-shard path reported
/// per-op; reads are side-effect free, so per-op is always safe to
/// retry and the client sees one shape regardless of the shard plan.
fn handle_read_batch(keys: &[Key], ctx: &NodeCtx) -> ClientResp {
    let run_shard = |batch: &BatchProposer, shard_keys: &[Key]| -> Vec<Result<Val, String>> {
        match batch.read_batch_merged(shard_keys) {
            Ok(rs) => rs.into_iter().map(|r| r.map_err(|e| e.to_string())).collect(),
            Err(e) => {
                let msg = e.to_string();
                shard_keys.iter().map(|_| Err(msg.clone())).collect()
            }
        }
    };
    if ctx.shards.len() == 1 {
        return ClientResp::Batch(run_shard(&ctx.batches[0], keys));
    }
    let by_shard = split_by_shard(ctx, keys.iter());
    scatter_shards(keys.len(), &by_shard, |s, idxs| {
        let shard_keys: Vec<Key> = idxs.iter().map(|&i| keys[i].clone()).collect();
        run_shard(&ctx.batches[s], &shard_keys)
    })
}

/// A minimal blocking client for the client protocol. One request in
/// flight at a time; the correlation id it stamps on each request lets
/// it discard stale replies to calls it abandoned (the server answers
/// out of order, so an interleaved concurrent client would use one
/// connection per thread — or a pending map like
/// [`crate::transport::tcp::TcpTransport`]'s).
pub struct Client {
    stream: TcpStream,
    next_corr: u64,
}

impl Client {
    /// Connects to a node's client port.
    pub fn connect(addr: &str) -> CasResult<Self> {
        let stream =
            TcpStream::connect(addr).map_err(|e| CasError::Transport(format!("{addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(Client { stream, next_corr: 0 })
    }

    /// Sends one request, awaits its response (matched by correlation
    /// id; replies to earlier abandoned calls are skipped).
    pub fn call(&mut self, req: &ClientReq) -> CasResult<ClientResp> {
        self.next_corr += 1;
        let corr = self.next_corr;
        write_envelope(&mut self.stream, corr, req)?;
        loop {
            let env: Envelope<ClientResp> = read_frame(&mut self.stream)?
                .ok_or_else(|| CasError::Transport("connection closed".into()))?;
            if env.corr == corr {
                return Ok(env.body);
            }
        }
    }

    /// Convenience: apply a change.
    pub fn change(&mut self, key: &str, change: ChangeFn) -> CasResult<Val> {
        match self.call(&ClientReq::Change { key: key.into(), change })? {
            ClientResp::Val(v) => Ok(v),
            ClientResp::Err(e) => Err(CasError::Transport(e)),
            other => Err(CasError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Convenience: linearizable read (1-RTT fast path when possible,
    /// identity-CAS fallback otherwise).
    pub fn get(&mut self, key: &str) -> CasResult<Val> {
        match self.call(&ClientReq::Read { key: key.into() })? {
            ClientResp::Val(v) => Ok(v),
            ClientResp::Err(e) => Err(CasError::Transport(e)),
            other => Err(CasError::Transport(format!("unexpected response {other:?}"))),
        }
    }

    /// Convenience: batched linearizable reads (per-shard shared
    /// quorum-read fan-outs). One result per key, in order.
    pub fn get_many(&mut self, keys: &[&str]) -> CasResult<Vec<Result<Val, String>>> {
        let keys: Vec<Key> = keys.iter().map(|k| k.to_string()).collect();
        match self.call(&ClientReq::ReadBatch { keys })? {
            ClientResp::Batch(items) => Ok(items),
            ClientResp::Err(e) => Err(CasError::Transport(e)),
            other => Err(CasError::Transport(format!("unexpected response {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    fn launch_cluster_opts(
        n: u64,
        shards: usize,
        stripes: usize,
        data: Option<&TempDir>,
        lease: Option<crate::proposer::LeaseOpts>,
    ) -> Vec<Node> {
        launch_cluster_backend(n, shards, stripes, data, lease, 0, Backend::Mem)
    }

    /// A single-shard mem cluster with server-edge read coalescing on.
    fn launch_cluster_coalesced(n: u64, coalesce_queue: usize) -> Vec<Node> {
        launch_cluster_full(n, 1, 1, None, None, 0, Backend::Mem, true, coalesce_queue)
    }

    fn launch_cluster_pooled(
        n: u64,
        shards: usize,
        stripes: usize,
        data: Option<&TempDir>,
        lease: Option<crate::proposer::LeaseOpts>,
        proposers_per_shard: usize,
    ) -> Vec<Node> {
        launch_cluster_backend(n, shards, stripes, data, lease, proposers_per_shard, Backend::Mem)
    }

    #[allow(clippy::too_many_arguments)]
    fn launch_cluster_backend(
        n: u64,
        shards: usize,
        stripes: usize,
        data: Option<&TempDir>,
        lease: Option<crate::proposer::LeaseOpts>,
        proposers_per_shard: usize,
        backend: Backend,
    ) -> Vec<Node> {
        launch_cluster_full(n, shards, stripes, data, lease, proposers_per_shard, backend, false, 0)
    }

    #[allow(clippy::too_many_arguments)]
    fn launch_cluster_full(
        n: u64,
        shards: usize,
        stripes: usize,
        data: Option<&TempDir>,
        lease: Option<crate::proposer::LeaseOpts>,
        proposers_per_shard: usize,
        backend: Backend,
        read_coalesce: bool,
        coalesce_queue: usize,
    ) -> Vec<Node> {
        // Two-phase bind: reserve acceptor AND client ports first so
        // every node knows every peer address before starting (a bind
        // learns a free port, releases it, the node re-binds — benign
        // race in tests).
        let reserve = || {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let peers: HashMap<u64, String> = (1..=n).map(|id| (id, reserve())).collect();
        let client_peers: HashMap<u64, String> = (1..=n).map(|id| (id, reserve())).collect();
        let cluster = ClusterConfig::majority(1, (1..=n).collect());
        let shard_plan = if shards > 1 {
            Some(ShardPlan::partition((1..=n).collect(), shards, None).unwrap())
        } else {
            None
        };
        (1..=n)
            .map(|id| {
                start_node(NodeOpts {
                    id,
                    acceptor_addr: peers[&id].clone(),
                    client_addr: client_peers[&id].clone(),
                    peers: peers.clone(),
                    client_peers: client_peers.clone(),
                    cluster: cluster.clone(),
                    shard_plan: shard_plan.clone(),
                    stripes,
                    io_threads: 0,
                    max_deferred: 0,
                    data_dir: data.map(|d| d.path().to_str().unwrap().to_string()),
                    backend,
                    checkpoint: None,
                    lease: lease.clone(),
                    proposers_per_shard,
                    router: RouterOpts::default(),
                    read_coalesce,
                    coalesce_queue,
                })
                .unwrap()
            })
            .collect()
    }

    fn launch_cluster_sharded(n: u64, shards: usize, data: Option<&TempDir>) -> Vec<Node> {
        launch_cluster_opts(n, shards, 1, data, None)
    }

    fn launch_cluster(n: u64, data: Option<&TempDir>) -> Vec<Node> {
        launch_cluster_sharded(n, 1, data)
    }

    #[test]
    fn client_req_resp_codec_roundtrip() {
        let reqs = vec![
            ClientReq::Change { key: "k".into(), change: ChangeFn::Add(1) },
            ClientReq::Batch {
                ops: vec![("a".into(), ChangeFn::Read), ("b".into(), ChangeFn::Set(2))],
            },
            ClientReq::Delete { key: "k".into() },
            ClientReq::Collect,
            ClientReq::Status,
            ClientReq::GcSync { key: "k".into(), min_counter: 9 },
            ClientReq::Read { key: "k".into() },
            ClientReq::ReadBatch { keys: vec!["a".into(), "b".into()] },
        ];
        for r in reqs {
            assert_eq!(ClientReq::from_bytes(&r.to_bytes()).unwrap(), r);
        }
        let resps = vec![
            ClientResp::Val(Val::Num { ver: 0, num: 1 }),
            ClientResp::Batch(vec![Ok(Val::Empty), Err("boom".into())]),
            ClientResp::Status("ok".into()),
            ClientResp::Synced { proposer_id: 3, age: 2 },
            ClientResp::Err("nope".into()),
        ];
        for r in resps {
            assert_eq!(ClientResp::from_bytes(&r.to_bytes()).unwrap(), r);
        }
    }

    #[test]
    fn full_node_cluster_serves_clients() {
        let nodes = launch_cluster(3, None);
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        assert_eq!(c.change("k", ChangeFn::Set(7)).unwrap().as_num(), Some(7));
        // Any node serves any client — read through a different node.
        let mut c2 = Client::connect(&nodes[2].client_addr.to_string()).unwrap();
        assert_eq!(c2.get("k").unwrap().as_num(), Some(7));
        // Batch through the data plane.
        let resp = c
            .call(&ClientReq::Batch {
                ops: (0..8).map(|i| (format!("b{i}"), ChangeFn::Set(i as i64))).collect(),
            })
            .unwrap();
        match resp {
            ClientResp::Batch(items) => {
                assert_eq!(items.len(), 8);
                for (i, item) in items.iter().enumerate() {
                    assert_eq!(item.as_ref().unwrap().as_num(), Some(i as i64));
                }
            }
            other => panic!("{other:?}"),
        }
        // Delete + collect.
        c.call(&ClientReq::Delete { key: "k".into() }).unwrap();
        match c.call(&ClientReq::Collect).unwrap() {
            ClientResp::Status(s) => assert!(s.contains("collected=1"), "{s}"),
            other => panic!("{other:?}"),
        }
        assert_eq!(c2.get("k").unwrap(), Val::Empty, "erased after GC");
        // Status works.
        assert!(matches!(c.call(&ClientReq::Status).unwrap(), ClientResp::Status(_)));
    }

    #[test]
    fn sharded_node_cluster_routes_shards() {
        // 6 nodes carved into 2 shards of 3 acceptors each.
        let nodes = launch_cluster_sharded(6, 2, None);
        assert_eq!(nodes[0].shard_proposers.len(), 2);
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        for i in 0..12 {
            c.change(&format!("k{i}"), ChangeFn::Set(i as i64)).unwrap();
        }
        // Any node serves any key, regardless of which shard hosts it.
        let mut c2 = Client::connect(&nodes[4].client_addr.to_string()).unwrap();
        for i in 0..12 {
            assert_eq!(c2.get(&format!("k{i}")).unwrap().as_num(), Some(i as i64));
        }
        // A batch spanning both shards reassembles in order.
        let resp = c
            .call(&ClientReq::Batch {
                ops: (0..12).map(|i| (format!("k{i}"), ChangeFn::Add(100))).collect(),
            })
            .unwrap();
        match resp {
            ClientResp::Batch(items) => {
                assert_eq!(items.len(), 12);
                for (i, item) in items.iter().enumerate() {
                    assert_eq!(item.as_ref().unwrap().as_num(), Some(100 + i as i64));
                }
            }
            other => panic!("{other:?}"),
        }
        // Delete + routed collect, through a different node than the
        // writer (exercises the cross-node, cross-shard GcSync path).
        c2.call(&ClientReq::Delete { key: "k0".into() }).unwrap();
        match c2.call(&ClientReq::Collect).unwrap() {
            ClientResp::Status(s) => assert!(s.contains("collected=1"), "{s}"),
            other => panic!("{other:?}"),
        }
        assert_eq!(c.get("k0").unwrap(), Val::Empty, "erased after GC");
        match c.call(&ClientReq::Status).unwrap() {
            ClientResp::Status(s) => assert!(s.contains("shards=2"), "{s}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn read_path_over_tcp() {
        let nodes = launch_cluster(3, None);
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        for i in 0..6 {
            c.change(&format!("r{i}"), ChangeFn::Set(i as i64)).unwrap();
        }
        // Single reads through a DIFFERENT node (forces the fallback:
        // the writer node's promise is foreign there) and through the
        // writer node (fast path: own promise).
        let mut c2 = Client::connect(&nodes[2].client_addr.to_string()).unwrap();
        for i in 0..6 {
            assert_eq!(c2.get(&format!("r{i}")).unwrap().as_num(), Some(i as i64));
            assert_eq!(c.get(&format!("r{i}")).unwrap().as_num(), Some(i as i64));
        }
        assert_eq!(c.get("absent").unwrap(), Val::Empty);
        // Batched reads reassemble in order.
        let many = c.get_many(&["r0", "r3", "absent", "r5"]).unwrap();
        assert_eq!(many.len(), 4);
        assert_eq!(many[0].as_ref().unwrap().as_num(), Some(0));
        assert_eq!(many[1].as_ref().unwrap().as_num(), Some(3));
        assert_eq!(many[2].as_ref().unwrap(), &Val::Empty);
        assert_eq!(many[3].as_ref().unwrap().as_num(), Some(5));
        // The node exports read-path counters.
        match c.call(&ClientReq::Status).unwrap() {
            ClientResp::Status(s) => {
                assert!(s.contains("read_fast="), "{s}");
                assert!(s.contains("read_fallback="), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn striped_node_cluster_serves_and_exports_wal_counters() {
        // 4-stripe nodes over durable storage: the whole client surface
        // works unchanged, and Status exports the shared-WAL counters
        // with appends outrunning fsyncs (group commit across stripes).
        let dir = TempDir::new("striped-node").unwrap();
        let nodes = launch_cluster_opts(3, 1, 4, Some(&dir), None);
        assert_eq!(nodes[0].stripes, 4);
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        for i in 0..12 {
            c.change(&format!("k{i}"), ChangeFn::Set(i as i64)).unwrap();
        }
        // Any node serves any key, whatever stripe it hashes to.
        let mut c2 = Client::connect(&nodes[2].client_addr.to_string()).unwrap();
        for i in 0..12 {
            assert_eq!(c2.get(&format!("k{i}")).unwrap().as_num(), Some(i as i64));
        }
        // Delete + collect walks the striped acceptors.
        c.call(&ClientReq::Delete { key: "k0".into() }).unwrap();
        match c.call(&ClientReq::Collect).unwrap() {
            ClientResp::Status(s) => assert!(s.contains("collected=1"), "{s}"),
            other => panic!("{other:?}"),
        }
        assert_eq!(c2.get("k0").unwrap(), Val::Empty, "erased after GC");
        match c.call(&ClientReq::Status).unwrap() {
            ClientResp::Status(s) => {
                assert!(s.contains("stripes=4"), "{s}");
                assert!(s.contains("inflight="), "{s}");
                assert!(s.contains("loop_wakeups="), "{s}");
                let field = |name: &str| -> u64 {
                    s.split_whitespace()
                        .find_map(|kv| kv.strip_prefix(name))
                        .and_then(|v| v.parse().ok())
                        .unwrap_or_else(|| panic!("missing {name} in {s}"))
                };
                assert!(field("wal_appends=") > 0, "writes must hit the shared WAL: {s}");
                assert!(
                    field("wal_fsyncs=") <= field("wal_appends="),
                    "fsyncs can never outrun appends: {s}"
                );
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn checkpoint_poller_truncates_wal_and_status_exports_progress() {
        // A single striped node with an automatic checkpoint cadence:
        // once the WAL outgrows `interval_records`, the background
        // poller runs the online pause-write-swap and `Status` starts
        // exporting checkpoint progress. Restarting the node then
        // replays only the delta (`replay_records` « total appends).
        let dir = TempDir::new("ckpt-node").unwrap();
        let reserve = || {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mk_opts = |acceptor_addr: String, client_addr: String| NodeOpts {
            id: 1,
            acceptor_addr,
            client_addr,
            peers: HashMap::new(),
            client_peers: HashMap::new(),
            cluster: ClusterConfig::majority(1, vec![1]),
            shard_plan: None,
            stripes: 4,
            io_threads: 0,
            max_deferred: 0,
            data_dir: Some(dir.path().to_str().unwrap().to_string()),
            backend: Backend::Mem,
            checkpoint: Some(crate::acceptor::CheckpointOpts {
                interval_records: 20,
                interval_bytes: 0,
            }),
            lease: None,
            proposers_per_shard: 0,
            router: RouterOpts::default(),
            read_coalesce: false,
            coalesce_queue: 0,
        };
        let node = start_node(mk_opts(reserve(), reserve())).unwrap();
        let mut c = Client::connect(&node.client_addr.to_string()).unwrap();
        for i in 0..60i64 {
            c.change(&format!("k{}", i % 8), ChangeFn::Set(i)).unwrap();
        }
        let field = |s: &str, name: &str| -> u64 {
            s.split_whitespace()
                .find_map(|kv| kv.strip_prefix(name))
                .and_then(|v| v.parse().ok())
                .unwrap_or_else(|| panic!("missing {name} in {s}"))
        };
        // The poller ticks every 50ms; give it a generous deadline.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let snapshot = loop {
            match c.call(&ClientReq::Status).unwrap() {
                ClientResp::Status(s) => {
                    if field(&s, "checkpoint_records=") > 0 {
                        break s;
                    }
                    assert!(
                        std::time::Instant::now() < deadline,
                        "checkpoint poller never fired: {s}"
                    );
                }
                other => panic!("{other:?}"),
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        };
        assert!(field(&snapshot, "last_checkpoint_us=") > 0, "{snapshot}");
        // 8 distinct keys live: the checkpoint holds the folded state,
        // not the append history.
        assert!(field(&snapshot, "checkpoint_records=") <= 9, "{snapshot}");
        // Data survives the swap, still served after the truncation.
        for i in 52..60i64 {
            assert_eq!(c.get(&format!("k{}", i % 8)).unwrap().as_num(), Some(i));
        }
        drop(c);
        drop(node);
        // Restart over the same dir: replay is checkpoint + delta only.
        let node2 = start_node(mk_opts(reserve(), reserve())).unwrap();
        let mut c2 = Client::connect(&node2.client_addr.to_string()).unwrap();
        for i in 52..60i64 {
            assert_eq!(c2.get(&format!("k{}", i % 8)).unwrap().as_num(), Some(i));
        }
        match c2.call(&ClientReq::Status).unwrap() {
            ClientResp::Status(s) => {
                assert!(field(&s, "checkpoint_records=") > 0, "{s}");
                assert!(
                    field(&s, "replay_records=") < 30,
                    "restart must replay only the post-checkpoint delta \
                     (60 historical appends): {s}"
                );
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lease_mode_node_serves_and_exports_counters() {
        use crate::proposer::LeaseOpts;
        // Short window: node 2's fallback read below must be able to
        // wait it out inside one retry budget.
        let lease = LeaseOpts {
            duration: std::time::Duration::from_millis(300),
            skew_bound: std::time::Duration::from_millis(50),
            renew_margin: std::time::Duration::ZERO,
        };
        let nodes = launch_cluster_opts(3, 1, 1, None, Some(lease));
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        c.change("k", ChangeFn::Set(7)).unwrap();
        // Repeat reads through the writer node: first acquires, the
        // rest serve from the per-shard lease manager's local state.
        for _ in 0..5 {
            assert_eq!(c.get("k").unwrap().as_num(), Some(7));
        }
        let (local, renews, _) = nodes[0].proposer.lease_stats();
        assert!(renews >= 1, "first read must run a grant round");
        assert!(local >= 3, "later reads must be lease-local, got {local}");
        match c.call(&ClientReq::Status).unwrap() {
            ClientResp::Status(s) => {
                assert!(s.contains("read_lease="), "{s}");
                assert!(s.contains("lease_renew="), "{s}");
                assert!(s.contains("lease_break="), "{s}");
            }
            other => panic!("{other:?}"),
        }
        // A different node's reads still work (denied the lease, they
        // fall back) — any node serves any client, leases or not.
        let mut c2 = Client::connect(&nodes[2].client_addr.to_string()).unwrap();
        assert_eq!(c2.get("k").unwrap().as_num(), Some(7));
    }

    #[test]
    fn proposer_pool_node_serves_and_exports_router_stats() {
        // A pool of 2 proposers per shard behind the stateless router:
        // any member serves any key of its shard, writes and reads from
        // different clients agree, GC still fences the right member, and
        // `Status` exports the routing-tier counters.
        let nodes = launch_cluster_pooled(3, 1, 1, None, None, 2);
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        for i in 0..16i64 {
            assert_eq!(c.change(&format!("p{i}"), ChangeFn::Set(i)).unwrap().as_num(), Some(i));
        }
        let mut c2 = Client::connect(&nodes[2].client_addr.to_string()).unwrap();
        for i in 0..16i64 {
            assert_eq!(c2.get(&format!("p{i}")).unwrap().as_num(), Some(i), "key p{i}");
        }
        // Delete + collect exercises GcSync across every pool member.
        c.call(&ClientReq::Delete { key: "p0".into() }).unwrap();
        match c.call(&ClientReq::Collect).unwrap() {
            ClientResp::Status(s) => assert!(s.contains("collected=1"), "{s}"),
            other => panic!("{other:?}"),
        }
        match c.call(&ClientReq::Status).unwrap() {
            ClientResp::Status(s) => {
                assert!(s.contains("pool_size=2"), "{s}");
                assert!(s.contains("routed="), "{s}");
                assert!(s.contains("redirected="), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn oversized_proposer_pool_is_rejected() {
        // Member pids live in 100k blocks; block 5 would collide with
        // the batch proposers' 500k block, so the knob is capped.
        let reserve = || {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = start_node(NodeOpts {
            id: 1,
            acceptor_addr: reserve(),
            client_addr: reserve(),
            peers: HashMap::new(),
            client_peers: HashMap::new(),
            cluster: ClusterConfig::majority(1, vec![1]),
            shard_plan: None,
            stripes: 1,
            io_threads: 0,
            max_deferred: 0,
            data_dir: None,
            backend: Backend::Mem,
            checkpoint: None,
            lease: None,
            proposers_per_shard: 6,
            router: RouterOpts::default(),
            read_coalesce: false,
            coalesce_queue: 0,
        })
        .unwrap_err();
        assert!(err.to_string().contains("capped at 5"), "{err}");
    }

    #[test]
    fn sharded_read_batch_spans_shards() {
        let nodes = launch_cluster_sharded(6, 2, None);
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        for i in 0..12 {
            c.change(&format!("k{i}"), ChangeFn::Set(i as i64)).unwrap();
        }
        let keys: Vec<String> = (0..12).map(|i| format!("k{i}")).collect();
        let refs: Vec<&str> = keys.iter().map(|s| s.as_str()).collect();
        // Read through a different node: the batch splits across both
        // shards and reassembles in order.
        let mut c2 = Client::connect(&nodes[5].client_addr.to_string()).unwrap();
        let many = c2.get_many(&refs).unwrap();
        assert_eq!(many.len(), 12);
        for (i, item) in many.iter().enumerate() {
            assert_eq!(item.as_ref().unwrap().as_num(), Some(i as i64), "key k{i}");
        }
    }

    #[test]
    fn client_protocol_pipelines_on_one_connection() {
        // Raw enveloped frames: two requests in flight on ONE client
        // connection; both replies arrive, matched by correlation id,
        // in whatever order they completed.
        let nodes = launch_cluster(3, None);
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        c.change("p0", ChangeFn::Set(1)).unwrap();
        let mut raw = TcpStream::connect(nodes[0].client_addr.to_string()).unwrap();
        write_envelope(&mut raw, 5, &ClientReq::Read { key: "p0".into() }).unwrap();
        write_envelope(&mut raw, 6, &ClientReq::Status).unwrap();
        let mut seen = std::collections::HashMap::new();
        for _ in 0..2 {
            let env: Envelope<ClientResp> = read_frame(&mut raw).unwrap().unwrap();
            seen.insert(env.corr, env.body);
        }
        match seen.remove(&5) {
            Some(ClientResp::Val(v)) => assert_eq!(v.as_num(), Some(1)),
            other => panic!("corr 5: {other:?}"),
        }
        assert!(matches!(seen.remove(&6), Some(ClientResp::Status(_))));
    }

    /// Partial-frame pin, client service: a request envelope dribbled
    /// one byte at a time across many readiness rounds must still be
    /// reassembled and answered with the right correlation id.
    #[test]
    fn client_envelope_dribbled_bytewise_gets_reply() {
        use std::io::Write;
        let nodes = launch_cluster(1, None);
        let mut s = TcpStream::connect(nodes[0].client_addr.to_string()).unwrap();
        s.set_nodelay(true).unwrap();
        let mut env = Vec::new();
        crate::codec::encode_envelope(9, &ClientReq::Status, &mut env);
        let mut frame = (env.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&env);
        for byte in frame {
            s.write_all(&[byte]).unwrap();
            s.flush().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let env: Envelope<ClientResp> = read_frame(&mut s).unwrap().expect("reply");
        assert_eq!(env.corr, 9);
        assert!(matches!(env.body, ClientResp::Status(_)));
    }

    /// Length-bomb pin, client service: a header declaring a frame past
    /// the limit kills only its own connection; clients already
    /// connected (and new ones) keep working.
    #[test]
    fn client_length_bomb_fails_only_its_connection() {
        use std::io::{Read, Write};
        let nodes = launch_cluster(1, None);
        let addr = nodes[0].client_addr.to_string();
        let mut c = Client::connect(&addr).unwrap();
        c.change("k", ChangeFn::Set(5)).unwrap();
        let mut bomb = TcpStream::connect(&addr).unwrap();
        bomb.write_all(&(crate::transport::tcp::MAX_FRAME + 1).to_le_bytes()).unwrap();
        bomb.flush().unwrap();
        bomb.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 1];
        match bomb.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(_) => panic!("length-bomb connection must be closed, got bytes back"),
        }
        // The pre-existing client connection is untouched.
        assert_eq!(c.get("k").unwrap().as_num(), Some(5));
    }

    #[test]
    fn disk_backend_cluster_serves_and_exports_gauges() {
        // A 4-stripe disk-backed cluster: the whole client surface
        // works unchanged on segment-file slots, `Status` reports the
        // backend and its gauges, and a restart over the same dirs
        // (still disk-backed) serves the same data.
        let dir = TempDir::new("disk-node").unwrap();
        let nodes =
            launch_cluster_backend(3, 1, 4, Some(&dir), None, 0, Backend::Disk);
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        for i in 0..12 {
            c.change(&format!("k{i}"), ChangeFn::Set(i as i64)).unwrap();
        }
        let mut c2 = Client::connect(&nodes[2].client_addr.to_string()).unwrap();
        for i in 0..12 {
            assert_eq!(c2.get(&format!("k{i}")).unwrap().as_num(), Some(i as i64));
        }
        // Delete + collect walks the on-disk indexes (Dump paging).
        c.call(&ClientReq::Delete { key: "k0".into() }).unwrap();
        match c.call(&ClientReq::Collect).unwrap() {
            ClientResp::Status(s) => assert!(s.contains("collected=1"), "{s}"),
            other => panic!("{other:?}"),
        }
        match c.call(&ClientReq::Status).unwrap() {
            ClientResp::Status(s) => {
                assert!(s.contains("backend=disk"), "{s}");
                assert!(s.contains("resident_keys="), "{s}");
                assert!(s.contains("replay_truncated_bytes=0"), "{s}");
                let field = |name: &str| -> u64 {
                    s.split_whitespace()
                        .find_map(|kv| kv.strip_prefix(name))
                        .and_then(|v| v.parse().ok())
                        .unwrap_or_else(|| panic!("missing {name} in {s}"))
                };
                assert!(field("index_pages=") > 0, "segments hold the slots: {s}");
                assert!(field("wal_appends=") > 0, "{s}");
            }
            other => panic!("{other:?}"),
        }
        drop(c);
        drop(c2);
        drop(nodes);
        let nodes =
            launch_cluster_backend(3, 1, 4, Some(&dir), None, 0, Backend::Disk);
        let mut c = Client::connect(&nodes[1].client_addr.to_string()).unwrap();
        for i in 1..12 {
            assert_eq!(c.get(&format!("k{i}")).unwrap().as_num(), Some(i as i64));
        }
    }

    #[test]
    fn durable_node_survives_restart() {
        let dir = TempDir::new("node").unwrap();
        // Bind concrete ports, write, then re-launch on the same ports
        // with the same data dir.
        let nodes = launch_cluster(3, Some(&dir));
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        c.change("persist", ChangeFn::Set(42)).unwrap();
        // The acceptor log files exist and are non-empty.
        let mut found = 0;
        for entry in std::fs::read_dir(dir.path()).unwrap() {
            let entry = entry.unwrap();
            if entry.file_name().to_string_lossy().starts_with("acceptor-") {
                assert!(entry.metadata().unwrap().len() > 0);
                found += 1;
            }
        }
        assert_eq!(found, 3);
    }

    // ---- server-edge read coalescing ----

    use crate::acceptor::Acceptor;
    use crate::msg::Request;
    use crate::proposer::ProposerOpts;
    use crate::runtime::{Engine, ScalarEngine, StepInput, StepOutput};
    use crate::transport::mem::MemTransport;
    use crate::transport::tcp::{spawn_acceptor_with, ReplyHook};
    use crate::transport::Transport;
    use std::sync::atomic::AtomicBool;
    use std::time::{Duration, Instant};

    /// A 3-acceptor TCP group whose `Read` replies spin until `gate`
    /// clears (the hook forces the deferred path, so the gate parks a
    /// worker, never the acceptor's event loop). Returns the batch
    /// proposer and a promise-free seeder for fast-path reads.
    fn gated_read_group(gate: &Arc<AtomicBool>) -> (Arc<BatchProposer>, Proposer) {
        let mut addrs = HashMap::new();
        for id in 1..=3u64 {
            let gate = Arc::clone(gate);
            let hook: ReplyHook = Arc::new(move |req, _resp| {
                if matches!(req, Request::Read { .. }) {
                    while gate.load(Ordering::Relaxed) {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            });
            let addr = spawn_acceptor_with("127.0.0.1:0", Acceptor::new(id), Some(hook)).unwrap();
            addrs.insert(id, addr.to_string());
        }
        let t = Arc::new(TcpTransport::new(addrs));
        let cfg = ClusterConfig::majority(1, vec![1, 2, 3]);
        // Seed WITHOUT piggybacking so no promise is left behind and
        // coalesced reads stay on the zero-write fast path.
        let seeder = Proposer::with_opts(
            7,
            cfg.clone(),
            t.clone(),
            ProposerOpts { piggyback: false, ..Default::default() },
        );
        let engine: Arc<dyn Engine> = Arc::new(ScalarEngine);
        let bp = Arc::new(BatchProposer::new(500_001, cfg, t, engine));
        (bp, seeder)
    }

    fn wait_until(what: &str, mut done: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !done() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn coalescer_solo_read_leads_immediately() {
        // No gate: an uncontended read must dispatch without waiting
        // for co-riders (the adaptive window is zero when idle).
        let gate = Arc::new(AtomicBool::new(false));
        let (bp, seeder) = gated_read_group(&gate);
        seeder.set("k", 7).unwrap();
        let co = ReadCoalescer::new(8);
        assert_eq!(co.read("k".into(), &bp).unwrap().as_num(), Some(7));
        assert_eq!(co.read("absent".into(), &bp).unwrap(), Val::Empty);
        assert_eq!(co.stats.snapshot(), (2, 2, 0), "two solo flights, no overflow");
        assert_eq!(co.queued(), 0);
    }

    #[test]
    fn coalescer_riders_share_one_fanout_and_hand_off() {
        let gate = Arc::new(AtomicBool::new(false));
        let (bp, seeder) = gated_read_group(&gate);
        for (i, k) in ["a", "b", "c", "d", "e"].iter().enumerate() {
            seeder.set(k, i as i64 + 1).unwrap();
        }
        let co = Arc::new(ReadCoalescer::new(8));
        // Leader dispatches into the closed gate and parks in flight.
        gate.store(true, Ordering::Relaxed);
        let leader = {
            let (co, bp) = (Arc::clone(&co), Arc::clone(&bp));
            std::thread::spawn(move || co.read("a".into(), &bp))
        };
        wait_until("leader in flight", || co.stats.snapshot().1 == 1);
        // Four reads arrive during the flight: all park as followers.
        let riders: Vec<_> = ["b", "c", "d", "e"]
            .iter()
            .map(|k| {
                let (co, bp, k) = (Arc::clone(&co), Arc::clone(&bp), k.to_string());
                std::thread::spawn(move || co.read(k, &bp))
            })
            .collect();
        wait_until("riders parked", || co.queued() == 4);
        gate.store(false, Ordering::Relaxed);
        assert_eq!(leader.join().unwrap().unwrap().as_num(), Some(1));
        for (i, h) in riders.into_iter().enumerate() {
            assert_eq!(h.join().unwrap().unwrap().as_num(), Some(i as i64 + 2));
        }
        // 5 reads, exactly 2 fan-outs: the leader's solo flight, then
        // ONE shared flight covering all four queued keys.
        assert_eq!(co.stats.snapshot(), (5, 2, 0));
        assert_eq!(co.queued(), 0);
    }

    #[test]
    fn coalescer_full_queue_overflows_without_parking() {
        let gate = Arc::new(AtomicBool::new(false));
        let (bp, seeder) = gated_read_group(&gate);
        seeder.set("a", 1).unwrap();
        seeder.set("b", 2).unwrap();
        let co = Arc::new(ReadCoalescer::new(1));
        gate.store(true, Ordering::Relaxed);
        let leader = {
            let (co, bp) = (Arc::clone(&co), Arc::clone(&bp));
            std::thread::spawn(move || co.read("a".into(), &bp))
        };
        wait_until("leader in flight", || co.stats.snapshot().1 == 1);
        let rider = {
            let (co, bp) = (Arc::clone(&co), Arc::clone(&bp));
            std::thread::spawn(move || co.read("b".into(), &bp))
        };
        wait_until("rider parked", || co.queued() == 1);
        // Queue full: the overflow read bypasses IMMEDIATELY (gate
        // still closed — it must not park behind the stalled flight).
        match co.read("c".into(), &bp) {
            Err(CasError::Overloaded { .. }) => {}
            other => panic!("expected Overloaded bypass, got {other:?}"),
        }
        gate.store(false, Ordering::Relaxed);
        assert_eq!(leader.join().unwrap().unwrap().as_num(), Some(1));
        assert_eq!(rider.join().unwrap().unwrap().as_num(), Some(2));
        let (reads, batches, overflows) = co.stats.snapshot();
        assert_eq!((reads, batches), (2, 2));
        assert_eq!(overflows, 1);
    }

    #[test]
    fn coalesced_node_serves_reads_and_exports_counters() {
        let nodes = launch_cluster_coalesced(3, 0);
        let addr = nodes[0].client_addr.to_string();
        let mut c = Client::connect(&addr).unwrap();
        c.change("h", ChangeFn::Set(7)).unwrap();
        // 8 concurrent readers hammer one hot key through one node:
        // every read is served through the coalescer (values still
        // linearizable), concurrent arrivals sharing fan-outs.
        let readers: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    for _ in 0..10 {
                        assert_eq!(c.get("h").unwrap().as_num(), Some(7));
                    }
                })
            })
            .collect();
        for h in readers {
            h.join().unwrap();
        }
        assert_eq!(c.get("absent").unwrap(), Val::Empty);
        match c.call(&ClientReq::Status).unwrap() {
            ClientResp::Status(s) => {
                let field = |name: &str| -> u64 {
                    s.split_whitespace()
                        .find_map(|kv| kv.strip_prefix(name))
                        .and_then(|v| v.parse().ok())
                        .unwrap_or_else(|| panic!("missing {name} in {s}"))
                };
                // 80 hot reads + 1 absent read, all through the
                // coalescer (queue depth 64 admits 8 readers, so none
                // overflowed to the routed path).
                assert_eq!(field("reads_coalesced="), 81, "{s}");
                assert!(field("coalesce_batches=") >= 1, "{s}");
                assert!(field("coalesce_batches=") <= 81, "{s}");
                assert!(s.contains("coalesce_avg="), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn coalesced_lease_node_keeps_lease_reads_local() {
        use crate::proposer::LeaseOpts;
        let lease = LeaseOpts {
            duration: std::time::Duration::from_millis(300),
            skew_bound: std::time::Duration::from_millis(50),
            renew_margin: std::time::Duration::ZERO,
        };
        let nodes = launch_cluster_full(3, 1, 1, None, Some(lease), 0, Backend::Mem, true, 0);
        let mut c = Client::connect(&nodes[0].client_addr.to_string()).unwrap();
        c.change("k", ChangeFn::Set(7)).unwrap();
        for _ in 0..5 {
            assert_eq!(c.get("k").unwrap().as_num(), Some(7));
        }
        let (local, renews, _) = nodes[0].proposer.lease_stats();
        assert!(renews >= 1, "first read must run a grant round");
        assert!(local >= 3, "later reads must be lease-local, got {local}");
        // Lease-tier reads never queue: the coalescer stays untouched
        // (tier 1 serves hits 0-RTT, misses keep the redirect-aware
        // routed path).
        match c.call(&ClientReq::Status).unwrap() {
            ClientResp::Status(s) => {
                assert!(s.contains("reads_coalesced=0"), "{s}");
                assert!(s.contains("coalesce_batches=0"), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    // ---- multi-shard batch dispatch (parallel scatter) ----

    /// First key (by probe order) routing to `shard`.
    fn key_for_shard(router: &ShardRouter, shard: usize) -> Key {
        (0..).map(|i| format!("k{i}")).find(|k| router.route(k) == shard).unwrap()
    }

    /// A NodeCtx over TWO single-acceptor shards whose `Read` and
    /// `Prepare` replies sleep `d` while `stall` is set — each shard's
    /// quorum round costs one deliberate RTT, so the dispatch strategy
    /// (serial vs concurrent) is directly visible in wall-clock time.
    fn two_shard_stalled_ctx(stall: &Arc<AtomicBool>, d: Duration) -> NodeCtx {
        let mut addrs = HashMap::new();
        for id in [1u64, 2] {
            let stall = Arc::clone(stall);
            let hook: ReplyHook = Arc::new(move |req, _resp| {
                if stall.load(Ordering::Relaxed)
                    && matches!(req, Request::Read { .. } | Request::Prepare { .. })
                {
                    std::thread::sleep(d);
                }
            });
            let addr = spawn_acceptor_with("127.0.0.1:0", Acceptor::new(id), Some(hook)).unwrap();
            addrs.insert(id, addr.to_string());
        }
        let t: Arc<dyn Transport> = Arc::new(TcpTransport::new(addrs));
        let engine: Arc<dyn Engine> = Arc::new(ScalarEngine);
        let cfgs =
            vec![ClusterConfig::majority(1, vec![1]), ClusterConfig::majority(1, vec![2])];
        ctx_over(cfgs.iter().map(|cfg| (cfg.clone(), t.clone(), engine.clone())).collect())
    }

    /// Hand-builds the client service's context over per-shard
    /// (config, transport, engine) triples — the test twin of
    /// `start_node`'s wiring, minus the sockets it doesn't need.
    fn ctx_over(shards: Vec<(ClusterConfig, Arc<dyn Transport>, Arc<dyn Engine>)>) -> NodeCtx {
        let proposers: Vec<Arc<Proposer>> = shards
            .iter()
            .enumerate()
            .map(|(s, (cfg, t, _))| Arc::new(Proposer::new(101 + s as u64, cfg.clone(), t.clone())))
            .collect();
        let batches: Vec<Arc<BatchProposer>> = shards
            .iter()
            .enumerate()
            .map(|(s, (cfg, t, engine))| {
                Arc::new(BatchProposer::new(
                    500_001 + s as u64,
                    cfg.clone(),
                    t.clone(),
                    engine.clone(),
                ))
            })
            .collect();
        let request_router = Arc::new(Router::new(
            proposers.iter().map(|p| vec![Arc::clone(p)]).collect(),
            RouterOpts::default(),
        ));
        let gc = Arc::new(GcProcess::with_id(
            shards[0].1.clone(),
            request_router.all_proposers(),
            900_001,
        ));
        NodeCtx {
            router: ShardRouter::new(shards.len()),
            shards: shards.into_iter().map(|(cfg, _, _)| cfg).collect(),
            proposers,
            request_router,
            batches,
            gc,
            stripes: 1,
            backend: Backend::Mem,
            wal_stats: None,
            backend_stats: None,
            loop_stats: Arc::new(LoopStats::default()),
            coalescers: None,
        }
    }

    #[test]
    fn multi_shard_batches_pay_one_stalled_rtt_not_the_sum() {
        let stall = Arc::new(AtomicBool::new(false));
        let d = Duration::from_millis(300);
        let ctx = two_shard_stalled_ctx(&stall, d);
        let k0 = key_for_shard(&ctx.router, 0);
        let k1 = key_for_shard(&ctx.router, 1);
        stall.store(true, Ordering::Relaxed);
        // A 2-shard read batch: each shard's fan-out stalls d, so the
        // serial dispatch this pins against would cost ≥ 2d.
        let start = Instant::now();
        match handle_read_batch(&[k0.clone(), k1.clone()], &ctx) {
            ClientResp::Batch(items) => {
                assert_eq!(items.len(), 2);
                for item in &items {
                    assert_eq!(item.as_ref().unwrap(), &Val::Empty);
                }
            }
            other => panic!("{other:?}"),
        }
        let read_elapsed = start.elapsed();
        assert!(read_elapsed >= d, "the stall must bite: {read_elapsed:?}");
        assert!(
            read_elapsed < d * 7 / 4,
            "2-shard read batch must dispatch shards concurrently \
             (~one stalled RTT, not two): {read_elapsed:?}"
        );
        // Same bound for the write path (Prepare is the stalled phase).
        let start = Instant::now();
        match handle_batch(&[(k0, ChangeFn::Set(1)), (k1, ChangeFn::Set(2))], &ctx) {
            ClientResp::Batch(items) => {
                assert_eq!(items[0].as_ref().unwrap().as_num(), Some(1));
                assert_eq!(items[1].as_ref().unwrap().as_num(), Some(2));
            }
            other => panic!("{other:?}"),
        }
        let write_elapsed = start.elapsed();
        assert!(write_elapsed >= d, "the stall must bite: {write_elapsed:?}");
        assert!(
            write_elapsed < d * 7 / 4,
            "2-shard write batch must dispatch shards concurrently: {write_elapsed:?}"
        );
        stall.store(false, Ordering::Relaxed);
    }

    // ---- per-op error shape (single- and multi-shard) ----

    /// An engine with no compiled variants: every fallback round fails
    /// whole-shard with `CasError::Runtime` before fanning out.
    struct NoEngine;
    impl Engine for NoEngine {
        fn pick_shape(&self, _acceptors: usize, _batch: usize) -> Option<(usize, usize)> {
            None
        }
        fn step(&self, _input: &StepInput) -> CasResult<StepOutput> {
            Err(CasError::Runtime("no engine".into()))
        }
    }

    /// One mem shard whose reads fail whole-shard: every acceptor is
    /// down (replies exhaust → fallback) and the fallback engine has no
    /// variants, so `read_batch_merged` returns `Err`, not per-op Oks.
    fn failing_shard() -> (ClusterConfig, Arc<dyn Transport>, Arc<dyn Engine>) {
        let t = Arc::new(MemTransport::new(3));
        let cfg = ClusterConfig::majority(1, t.acceptor_ids());
        for id in t.acceptor_ids() {
            t.set_down(id, true);
        }
        let transport: Arc<dyn Transport> = t;
        let engine: Arc<dyn Engine> = Arc::new(NoEngine);
        (cfg, transport, engine)
    }

    #[test]
    fn read_batch_whole_shard_failure_is_per_op_on_one_shard() {
        // The single-shard shape used to collapse a whole-shard error
        // into ClientResp::Err while the multi-shard path answered
        // per-op; both shapes must now agree (reads are side-effect
        // free, so per-op errors are always safe to retry).
        let ctx = ctx_over(vec![failing_shard()]);
        match handle_read_batch(&["a".into(), "b".into()], &ctx) {
            ClientResp::Batch(items) => {
                assert_eq!(items.len(), 2);
                for item in &items {
                    let e = item.as_ref().unwrap_err();
                    assert!(e.contains("no engine variant"), "{e}");
                }
            }
            other => panic!("whole-shard failure must stay per-op, got {other:?}"),
        }
    }

    #[test]
    fn read_batch_whole_shard_failure_is_per_op_across_shards() {
        // Shard 0 fails whole-shard, shard 1 is healthy: the batch
        // reassembles per-op errors beside per-op values.
        let healthy_t = Arc::new(MemTransport::new(3));
        let healthy_cfg = ClusterConfig::majority(1, healthy_t.acceptor_ids());
        let healthy: (ClusterConfig, Arc<dyn Transport>, Arc<dyn Engine>) =
            (healthy_cfg, healthy_t, Arc::new(ScalarEngine));
        let ctx = ctx_over(vec![failing_shard(), healthy]);
        let k0 = key_for_shard(&ctx.router, 0);
        let k1 = key_for_shard(&ctx.router, 1);
        ctx.batches[1].execute(&[(k1.clone(), ChangeFn::Set(9))]).unwrap();
        match handle_read_batch(&[k0, k1], &ctx) {
            ClientResp::Batch(items) => {
                assert_eq!(items.len(), 2);
                let e = items[0].as_ref().unwrap_err();
                assert!(e.contains("no engine variant"), "{e}");
                assert_eq!(items[1].as_ref().unwrap().as_num(), Some(9));
            }
            other => panic!("{other:?}"),
        }
    }
}
