"""Build-time Python: JAX/Pallas kernels + AOT lowering. Never imported
at request time — the Rust coordinator loads the compiled artifacts."""
