"""L2: the CASPaxos batched data-plane step as a JAX computation.

``caspaxos_step`` fuses the proposer's two compute stages — quorum value
selection (pick the accepted value with the highest ballot out of A
replies) and change-function application — over a batch of B independent
registers, calling the L1 Pallas kernels so the whole step lowers into
one HLO module. ``aot.py`` lowers one variant per (A, B) shape the Rust
coordinator wants to serve; Python never runs at request time.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import apply_cas as apply_mod  # noqa: E402
from .kernels import select_max_ballot as select_mod  # noqa: E402

# Shape variants compiled by default: (acceptors, batch).
DEFAULT_VARIANTS = [(3, 64), (3, 256), (5, 64), (5, 256)]


def caspaxos_step(ballots, states, ops, args):
    """select_max_ballot ∘ apply_cas over a B-key batch.

    Args:
      ballots: ``[A, B] int64`` packed ballots (-1 = absent).
      states: ``[A, B, 2] int64`` packed per-acceptor states.
      ops: ``[B] int32`` op codes.
      args: ``[B, 2] int64`` op arguments.

    Returns:
      ``(next_states [B, 2], accepted [B] int32, max_ballot [B])`` —
      what the proposer sends in its accept fan-out, per key.
    """
    chosen, max_ballot = select_mod.select_max_ballot(ballots, states)
    next_states, accepted = apply_mod.apply_cas(chosen, ops, args)
    return next_states, accepted, max_ballot


def example_args(a, b):
    """ShapeDtypeStructs for lowering an (A=a, B=b) variant."""
    return (
        jax.ShapeDtypeStruct((a, b), jnp.int64),
        jax.ShapeDtypeStruct((a, b, 2), jnp.int64),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b, 2), jnp.int64),
    )


def lower_variant(a, b):
    """Lowers caspaxos_step for fixed (A, B) shapes."""
    return jax.jit(caspaxos_step).lower(*example_args(a, b))
