"""L1 Pallas kernels for the CASPaxos batched data plane."""
