"""L1 Pallas kernel: batched change-function application.

The write half of the CASPaxos data plane: apply the §2.2 change
functions (read / init / CAS / set / add / tombstone) to a batch of B
current states in one vector op. Semantics are differential-tested
against :mod:`ref` (pytest) and against the Rust scalar
``ChangeFn::apply`` (cargo test, via the shared op-code table).

Same TPU mapping as ``select_max_ballot``: B on the lane axis in
128-wide VMEM blocks, branch-free select chains on the VPU.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _apply_kernel(states_ref, ops_ref, args_ref, out_state_ref, out_acc_ref):
    states = states_ref[...]  # [Bb, 2]
    ops = ops_ref[...]  # [Bb]
    args = args_ref[...]  # [Bb, 2]

    ver, num = states[:, 0], states[:, 1]
    expect, val = args[:, 0], args[:, 1]
    is_num = ver >= 0

    init_hit = ~is_num
    init_next = jnp.where(
        init_hit[:, None], jnp.stack([jnp.zeros_like(ver), val], -1), states
    )
    cas_hit = is_num & (ver == expect)
    cas_next = jnp.where(cas_hit[:, None], jnp.stack([expect + 1, val], -1), states)
    set_next = jnp.stack([jnp.where(is_num, ver + 1, 0), val], -1)
    add_next = jnp.stack(
        [jnp.where(is_num, ver + 1, 0), jnp.where(is_num, num + val, val)], -1
    )
    tomb_next = jnp.stack(
        [jnp.full_like(ver, ref.VER_TOMBSTONE), jnp.zeros_like(num)], -1
    )

    next_states = states  # READ default
    accepted = jnp.ones_like(ops)
    for code, nxt in [
        (ref.OP_INIT, init_next),
        (ref.OP_CAS, cas_next),
        (ref.OP_SET, set_next),
        (ref.OP_ADD, add_next),
        (ref.OP_TOMBSTONE, tomb_next),
    ]:
        hit = ops == code
        next_states = jnp.where(hit[:, None], nxt, next_states)
    accepted = jnp.where(
        (ops == ref.OP_CAS) & ~cas_hit, jnp.zeros_like(ops), accepted
    )
    out_state_ref[...] = next_states
    out_acc_ref[...] = accepted


def apply_cas(states, ops, args, *, block_b=128):
    """Pallas version of :func:`ref.apply_cas`.

    Args:
      states: ``[B, 2] int64``.
      ops: ``[B] int32``.
      args: ``[B, 2] int64``.
      block_b: lane-block size.

    Returns:
      ``(next_states [B, 2] int64, accepted [B] int32)``.
    """
    b = ops.shape[0]
    bb = min(block_b, b)
    assert b % bb == 0, f"batch {b} not divisible by block {bb}"
    grid = (b // bb,)
    return pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 2), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb, 2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 2), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 2), jnp.int64),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=True,
    )(states, ops, args)
