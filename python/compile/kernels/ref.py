"""Pure-jnp oracle for the CASPaxos data-plane kernels.

This is the sequential specification the Pallas kernels (and, through the
shared op-code table, the Rust scalar path) are differential-tested
against. Shapes and encodings:

* ballots  ``[A, B] int64``  — packed ballot per (acceptor, key);
  ``-1`` marks "no reply / empty slot". Packing (see rust ``ballot.rs``):
  ``counter << 20 | proposer`` so integer order == ballot order.
* states   ``[A, B, 2] int64`` — packed register state per (acceptor,
  key): ``[ver, num]``; ``ver == -1`` is ∅, ``ver == -2`` a tombstone.
* ops      ``[B] int32`` — op codes (rust ``state.rs::opcode``).
* args     ``[B, 2] int64`` — op arguments ``[expect_or_unused, value]``.

``select_max_ballot``: the proposer rule "pick the value of the tuple
with the highest ballot number" vectorized over a key batch.

``apply_cas``: the §2.2 change functions vectorized over a key batch.
Semantics mirror ``ChangeFn::apply`` exactly (wrapping i64 adds
included).
"""

import jax.numpy as jnp

# Op codes — keep in sync with rust/src/state.rs::opcode.
OP_READ = 0
OP_INIT = 1
OP_CAS = 2
OP_SET = 3
OP_ADD = 4
OP_TOMBSTONE = 5

VER_EMPTY = -1
VER_TOMBSTONE = -2


def select_max_ballot(ballots, states):
    """Chooses, per key, the acceptor state with the highest ballot.

    Args:
      ballots: ``[A, B] int64``; -1 = absent.
      states: ``[A, B, 2] int64``.

    Returns:
      ``(chosen [B, 2] int64, max_ballot [B] int64)``. Keys where every
      ballot is -1 yield the ∅ state ``[-1, 0]``.
    """
    ballots = jnp.asarray(ballots, jnp.int64)
    states = jnp.asarray(states, jnp.int64)
    # First max wins ties; protocol ballots are globally unique, so a tie
    # can only pair identical (ballot, value) replicas — value-equivalent.
    idx = jnp.argmax(ballots, axis=0)
    max_ballot = jnp.max(ballots, axis=0)
    chosen = jnp.take_along_axis(states, idx[None, :, None], axis=0)[0]
    empty = jnp.stack(
        [jnp.full_like(max_ballot, VER_EMPTY), jnp.zeros_like(max_ballot)], axis=-1
    )
    chosen = jnp.where((max_ballot < 0)[:, None], empty, chosen)
    return chosen, max_ballot


def apply_cas(states, ops, args):
    """Applies the §2.2 change functions to a batch of current states.

    Args:
      states: ``[B, 2] int64`` current (ver, num).
      ops: ``[B] int32`` op codes.
      args: ``[B, 2] int64`` (expect, value).

    Returns:
      ``(next_states [B, 2] int64, accepted [B] int32)``.
    """
    states = jnp.asarray(states, jnp.int64)
    ops = jnp.asarray(ops, jnp.int32)
    args = jnp.asarray(args, jnp.int64)

    ver, num = states[:, 0], states[:, 1]
    expect, val = args[:, 0], args[:, 1]
    is_num = ver >= 0

    # READ: x -> x.
    read_next = states
    read_acc = jnp.ones_like(ops)

    # INIT: ∅/tombstone -> (0, val); otherwise no-op (still accepted).
    init_hit = ~is_num
    init_next = jnp.where(
        init_hit[:, None], jnp.stack([jnp.zeros_like(ver), val], -1), states
    )
    init_acc = jnp.ones_like(ops)

    # CAS: Num(ver == expect) -> (expect+1, val) else reject.
    cas_hit = is_num & (ver == expect)
    cas_next = jnp.where(cas_hit[:, None], jnp.stack([expect + 1, val], -1), states)
    cas_acc = cas_hit.astype(jnp.int32)

    # SET: -> (ver+1, val) with non-Num counting as ver -1.
    set_ver = jnp.where(is_num, ver + 1, 0)
    set_next = jnp.stack([set_ver, val], -1)
    set_acc = jnp.ones_like(ops)

    # ADD: Num -> (ver+1, num + val) (wrapping); else (0, val).
    add_ver = jnp.where(is_num, ver + 1, 0)
    add_num = jnp.where(is_num, num + val, val)
    add_next = jnp.stack([add_ver, add_num], -1)
    add_acc = jnp.ones_like(ops)

    # TOMBSTONE: -> (-2, 0).
    tomb_next = jnp.broadcast_to(jnp.array([VER_TOMBSTONE, 0], jnp.int64), states.shape)
    tomb_acc = jnp.ones_like(ops)

    next_states = read_next
    accepted = read_acc
    for code, nxt, acc in [
        (OP_INIT, init_next, init_acc),
        (OP_CAS, cas_next, cas_acc),
        (OP_SET, set_next, set_acc),
        (OP_ADD, add_next, add_acc),
        (OP_TOMBSTONE, tomb_next, tomb_acc),
    ]:
        hit = ops == code
        next_states = jnp.where(hit[:, None], nxt, next_states)
        accepted = jnp.where(hit, acc, accepted)
    return next_states, accepted


def caspaxos_step(ballots, states, ops, args):
    """The full L2 step: quorum value selection ∘ change application."""
    chosen, max_ballot = select_max_ballot(ballots, states)
    next_states, accepted = apply_cas(chosen, ops, args)
    return next_states, accepted, max_ballot
