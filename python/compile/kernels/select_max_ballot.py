"""L1 Pallas kernel: quorum value selection.

The proposer rule from §2.2 — "picks the value of the tuple with the
highest ballot number" — vectorized over a batch of B keys × A acceptor
replies. This is the read half of the CASPaxos data plane the Rust
coordinator batches through PJRT.

TPU mapping (DESIGN.md §Hardware-Adaptation): the key batch B rides the
lane axis in 128-wide blocks; the acceptor axis A (3–8) is statically
unrolled, so each grid step keeps an A×128×2 i64 working set (<8 KiB) in
VMEM. Pure VPU compare/select — the roofline is VMEM bandwidth.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the interpret path *is* the production
artifact here (see /opt/xla-example/README.md).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _select_kernel(ballots_ref, states_ref, out_state_ref, out_ballot_ref):
    ballots = ballots_ref[...]  # [A, Bb]
    states = states_ref[...]  # [A, Bb, 2]
    a_total = ballots.shape[0]
    best_b = ballots[0]
    best_s = states[0]
    # Static unroll over the (small) acceptor axis; strictly-greater keeps
    # the first maximum, matching the jnp.argmax oracle.
    for a in range(1, a_total):
        take = ballots[a] > best_b
        best_s = jnp.where(take[:, None], states[a], best_s)
        best_b = jnp.where(take, ballots[a], best_b)
    empty = jnp.stack(
        [jnp.full_like(best_b, ref.VER_EMPTY), jnp.zeros_like(best_b)], axis=-1
    )
    out_state_ref[...] = jnp.where((best_b < 0)[:, None], empty, best_s)
    out_ballot_ref[...] = best_b


def select_max_ballot(ballots, states, *, block_b=128):
    """Pallas version of :func:`ref.select_max_ballot`.

    Args:
      ballots: ``[A, B] int64``.
      states: ``[A, B, 2] int64``.
      block_b: lane-block size (B must divide by it or be smaller).

    Returns:
      ``(chosen [B, 2] int64, max_ballot [B] int64)``.
    """
    a, b = ballots.shape
    bb = min(block_b, b)
    assert b % bb == 0, f"batch {b} not divisible by block {bb}"
    grid = (b // bb,)
    return pl.pallas_call(
        _select_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((a, bb), lambda i: (0, i)),
            pl.BlockSpec((a, bb, 2), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 2), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 2), jnp.int64),
            jax.ShapeDtypeStruct((b,), jnp.int64),
        ],
        interpret=True,
    )(ballots, states)
