"""AOT export: lower the L2 step to HLO text for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example.

Usage::

    python -m compile.aot --outdir ../artifacts

writes ``caspaxos_step_a{A}_b{B}.hlo.txt`` per default variant plus a
``manifest.txt`` (one ``name a b path`` line per artifact) the Rust
artifact registry reads.
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """Converts a lowered jax computation to XLA HLO text.

    ``print_large_constants=True`` is REQUIRED: the default printer elides
    big array constants as ``constant({...})`` inside region bodies, and
    xla_extension 0.5.1's text parser silently accepts the placeholder —
    the executable then reads garbage where the constant should be. Found
    the hard way; pinned by test_export_prints_large_constants.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export(outdir, variants=None):
    """Lowers every variant; returns [(name, a, b, path)]."""
    variants = variants or model.DEFAULT_VARIANTS
    os.makedirs(outdir, exist_ok=True)
    rows = []
    for a, b in variants:
        name = f"caspaxos_step_a{a}_b{b}"
        path = os.path.join(outdir, f"{name}.hlo.txt")
        text = to_hlo_text(model.lower_variant(a, b))
        with open(path, "w") as f:
            f.write(text)
        rows.append((name, a, b, path))
        print(f"wrote {path} ({len(text)} chars)")
    manifest = os.path.join(outdir, "manifest.txt")
    with open(manifest, "w") as f:
        for name, a, b, path in rows:
            f.write(f"{name} {a} {b} {os.path.basename(path)}\n")
    print(f"wrote {manifest}")
    return rows


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--outdir", default="../artifacts")
    parser.add_argument(
        "--variants",
        default=None,
        help="comma-separated a:b pairs, e.g. 3:64,5:256",
    )
    args = parser.parse_args()
    variants = None
    if args.variants:
        variants = [tuple(map(int, v.split(":"))) for v in args.variants.split(",")]
    export(args.outdir, variants)


if __name__ == "__main__":
    main()
