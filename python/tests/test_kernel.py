"""Differential tests: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and values; explicit cases pin the protocol
edge cases (empty quorum, tombstones, CAS rejection, i64 wrap).
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import apply_cas as ap  # noqa: E402
from compile.kernels import ref  # noqa: E402
from compile.kernels import select_max_ballot as sel  # noqa: E402

I64 = np.int64
I64_MIN, I64_MAX = np.iinfo(I64).min, np.iinfo(I64).max


def np_eq(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


@st.composite
def select_inputs(draw):
    a = draw(st.integers(min_value=1, max_value=7))
    b = draw(st.sampled_from([1, 2, 8, 64, 128, 256]))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.RandomState(seed)
    ballots = rng.randint(-1, 1 << 40, size=(a, b)).astype(I64)
    # Sprinkle all-absent keys.
    absent = rng.rand(b) < 0.2
    ballots[:, absent] = -1
    states = rng.randint(-2, 1 << 30, size=(a, b, 2)).astype(I64)
    return ballots, states


@st.composite
def apply_inputs(draw):
    b = draw(st.sampled_from([1, 2, 8, 64, 128, 256, 512]))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.RandomState(seed)
    states = rng.randint(-2, 100, size=(b, 2)).astype(I64)
    ops = rng.randint(0, 6, size=(b,)).astype(np.int32)
    args = rng.randint(-100, 100, size=(b, 2)).astype(I64)
    # Force some CAS hits (expect == current version).
    hit = rng.rand(b) < 0.3
    args[hit, 0] = states[hit, 0]
    return states, ops, args


@settings(max_examples=40, deadline=None)
@given(select_inputs())
def test_select_matches_ref(inputs):
    ballots, states = inputs
    c_ref, m_ref = ref.select_max_ballot(ballots, states)
    c_pl, m_pl = sel.select_max_ballot(ballots, states)
    np_eq(c_ref, c_pl, "chosen state mismatch")
    np_eq(m_ref, m_pl, "max ballot mismatch")


@settings(max_examples=40, deadline=None)
@given(apply_inputs())
def test_apply_matches_ref(inputs):
    states, ops, args = inputs
    n_ref, a_ref = ref.apply_cas(states, ops, args)
    n_pl, a_pl = ap.apply_cas(states, ops, args)
    np_eq(n_ref, n_pl, "next state mismatch")
    np_eq(a_ref, a_pl, "accepted mismatch")


def test_select_all_absent_yields_empty():
    ballots = np.full((3, 64), -1, I64)
    states = np.random.RandomState(1).randint(0, 9, size=(3, 64, 2)).astype(I64)
    chosen, max_b = sel.select_max_ballot(ballots, states)
    np_eq(chosen, np.tile([ref.VER_EMPTY, 0], (64, 1)))
    np_eq(max_b, np.full(64, -1, I64))


def test_select_picks_highest_ballot_value():
    ballots = np.array([[5, 1], [9, -1], [7, 3]], I64)
    states = np.array(
        [[[0, 10], [0, 40]], [[1, 20], [0, 50]], [[2, 30], [1, 60]]], I64
    )
    chosen, max_b = sel.select_max_ballot(ballots, states)
    np_eq(chosen, [[1, 20], [1, 60]])
    np_eq(max_b, [9, 3])


def test_cas_hit_and_miss():
    states = np.array([[5, 10], [5, 10], [-1, 0], [-2, 0]], I64)
    ops = np.full(4, ref.OP_CAS, np.int32)
    args = np.array([[5, 99], [4, 99], [0, 99], [0, 99]], I64)
    nxt, acc = ap.apply_cas(states, ops, args)
    np_eq(nxt, [[6, 99], [5, 10], [-1, 0], [-2, 0]])
    np_eq(acc, [1, 0, 0, 0])


def test_init_only_on_empty_or_tombstone():
    states = np.array([[-1, 0], [-2, 0], [3, 7]], I64)
    ops = np.full(3, ref.OP_INIT, np.int32)
    args = np.array([[0, 42], [0, 42], [0, 42]], I64)
    nxt, acc = ap.apply_cas(states, ops, args)
    np_eq(nxt, [[0, 42], [0, 42], [3, 7]])
    np_eq(acc, [1, 1, 1])


def test_add_wraps_like_rust():
    states = np.array([[0, I64_MAX]], I64)
    ops = np.array([ref.OP_ADD], np.int32)
    args = np.array([[0, 1]], I64)
    with np.errstate(over="ignore"):
        nxt, acc = ap.apply_cas(states, ops, args)
    assert int(nxt[0, 1]) == I64_MIN, "i64 add must wrap (two's complement)"
    np_eq(acc, [1])


def test_add_treats_empty_as_zero():
    states = np.array([[-1, 0], [-2, 0]], I64)
    ops = np.full(2, ref.OP_ADD, np.int32)
    args = np.array([[0, 5], [0, -3]], I64)
    nxt, _ = ap.apply_cas(states, ops, args)
    np_eq(nxt, [[0, 5], [0, -3]])


def test_tombstone_overwrites_everything():
    states = np.array([[9, 9], [-1, 0]], I64)
    ops = np.full(2, ref.OP_TOMBSTONE, np.int32)
    args = np.zeros((2, 2), I64)
    nxt, acc = ap.apply_cas(states, ops, args)
    np_eq(nxt, [[-2, 0], [-2, 0]])
    np_eq(acc, [1, 1])


def test_read_is_identity():
    rng = np.random.RandomState(3)
    states = rng.randint(-2, 50, size=(128, 2)).astype(I64)
    ops = np.full(128, ref.OP_READ, np.int32)
    args = rng.randint(-5, 5, size=(128, 2)).astype(I64)
    nxt, acc = ap.apply_cas(states, ops, args)
    np_eq(nxt, states)
    np_eq(acc, np.ones(128, np.int32))


@pytest.mark.parametrize("block_b", [32, 64, 128])
def test_blocking_is_transparent(block_b):
    rng = np.random.RandomState(7)
    ballots = rng.randint(-1, 99, size=(3, 256)).astype(I64)
    states = rng.randint(-2, 50, size=(3, 256, 2)).astype(I64)
    c_ref, m_ref = ref.select_max_ballot(ballots, states)
    c_pl, m_pl = sel.select_max_ballot(ballots, states, block_b=block_b)
    np_eq(c_ref, c_pl)
    np_eq(m_ref, m_pl)
