"""L2 model tests: composition, shapes, and the AOT export path."""

import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from compile import aot, model  # noqa: E402
from compile.kernels import ref  # noqa: E402

I64 = np.int64


def random_inputs(a, b, seed=0):
    rng = np.random.RandomState(seed)
    ballots = rng.randint(-1, 1000, size=(a, b)).astype(I64)
    states = rng.randint(-2, 100, size=(a, b, 2)).astype(I64)
    ops = rng.randint(0, 6, size=(b,)).astype(np.int32)
    args = rng.randint(-10, 10, size=(b, 2)).astype(I64)
    return ballots, states, ops, args


def test_step_matches_ref_composition():
    ballots, states, ops, args = random_inputs(3, 64)
    n1, a1, m1 = model.caspaxos_step(ballots, states, ops, args)
    n2, a2, m2 = ref.caspaxos_step(ballots, states, ops, args)
    np.testing.assert_array_equal(np.array(n1), np.array(n2))
    np.testing.assert_array_equal(np.array(a1), np.array(a2))
    np.testing.assert_array_equal(np.array(m1), np.array(m2))


def test_step_output_shapes():
    for a, b in [(3, 64), (5, 256)]:
        ballots, states, ops, args = random_inputs(a, b, seed=a * b)
        n, acc, m = model.caspaxos_step(ballots, states, ops, args)
        assert n.shape == (b, 2) and str(n.dtype) == "int64"
        assert acc.shape == (b,) and str(acc.dtype) == "int32"
        assert m.shape == (b,) and str(m.dtype) == "int64"


def test_lowering_produces_hlo_text():
    lowered = model.lower_variant(3, 64)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "s64[3,64]" in text, "input layout must be visible in HLO"


def test_export_writes_manifest():
    with tempfile.TemporaryDirectory() as d:
        rows = aot.export(d, variants=[(3, 64)])
        assert len(rows) == 1
        name, a, b, path = rows[0]
        assert os.path.exists(path)
        manifest = open(os.path.join(d, "manifest.txt")).read().strip()
        assert manifest == f"caspaxos_step_a3_b64 3 64 caspaxos_step_a3_b64.hlo.txt"


def test_export_prints_large_constants():
    # Regression: the default HLO printer elides >10-element constants as
    # "{...}", which xla_extension 0.5.1 parses into garbage memory.
    lowered = model.lower_variant(3, 64)
    text = aot.to_hlo_text(lowered)
    assert "constant({...})" not in text, "elided constant would corrupt the artifact"


def test_full_round_simulation_via_model():
    # Simulate the proposer data plane for one batch: three acceptors
    # agree on key states; ops produce the accept-phase payloads.
    b = 64
    ballots = np.tile(np.array([[7], [7], [7]], I64), (1, b))
    base = np.stack([np.arange(b), np.arange(b) * 10], -1).astype(I64)
    states = np.tile(base[None], (3, 1, 1))
    ops = np.full(b, ref.OP_ADD, np.int32)
    args = np.stack([np.zeros(b), np.ones(b)], -1).astype(I64)
    nxt, acc, maxb = model.caspaxos_step(ballots, states, ops, args)
    np.testing.assert_array_equal(np.array(maxb), np.full(b, 7))
    np.testing.assert_array_equal(np.array(acc), np.ones(b, np.int32))
    np.testing.assert_array_equal(np.array(nxt)[:, 1], base[:, 1] + 1)
    np.testing.assert_array_equal(np.array(nxt)[:, 0], base[:, 0] + 1)
