//! Offline stub of the `xla` PJRT bindings.
//!
//! The real `xla` crate links libxla through the PJRT C API and executes
//! AOT-compiled HLO. This build environment carries no such shared
//! library, so every entry point here fails at *runtime* with a clear
//! error while keeping the whole dependency graph compilable offline.
//! Callers (see `caspaxos::runtime`) already probe for artifacts and
//! handle `PjRtClient::cpu()` failure by falling back to the pure-Rust
//! scalar engine, so swapping the real crate back in is a Cargo.toml
//! change, not a code change.
//!
//! The API surface mirrors exactly the subset the caspaxos runtime uses:
//! client construction + compile, executable execution, HLO parsing, and
//! literal packing/unpacking.

use std::fmt;
use std::path::Path;

/// Error type returned by every fallible stub entry point.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("XLA/PJRT is unavailable in this offline build (stub crate)".to_string())
}

/// Stub PJRT client. [`PjRtClient::cpu`] always fails, which is the
/// signal `caspaxos::runtime` uses to fall back to the scalar engine.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Would create a CPU PJRT client; always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    /// Platform diagnostics string.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Would compile an XLA computation; always fails in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// Stub compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Would execute the program; always fails in the stub.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Stub device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Would transfer the buffer to a host literal; always fails.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Would parse an HLO text file; always fails in the stub.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Stub XLA computation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wraps a parsed proto (infallible in the real crate too).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub host literal. Construction is infallible (mirroring the real
/// crate); every operation on it fails.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Would pack a rank-1 array; the stub stores nothing.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Would reshape; always fails in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    /// Would unpack to a host vector; always fails in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    /// Would split a 3-tuple literal; always fails in the stub.
    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal), Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
        let lit = Literal::vec1(&[1i64, 2, 3]);
        assert!(lit.reshape(&[3]).is_err());
        assert!(lit.to_vec::<i64>().is_err());
        assert!(lit.clone().to_tuple3().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline"), "{err}");
    }
}
